"""Storage-tier EPS (repro.core.tierstore) invariants.

Three claims, mirroring how every prior relay knob was proven:

* the SegmentStore is checkpoint-grade: staged-fsync-rename writes,
  whole-file verification at open, per-row verification on every read,
  bounded retry on transient errors, quarantine + rebuild on rot;
* the tier chain is a pure PLACEMENT change: for every (G, prefetch,
  pack, K) point, l2l and l2l-p training/prefill/decode through the
  disk tier are bit-identical to the host-only relay — including runs
  with a forced transient-retry and a quarantine-rebuild mid-relay;
* the memory model certifies the paper-class deliverable: a >100B-param
  arch fits a 16 GiB device budget with the overflow accounted on disk
  by the SAME demote_plan the runtime executes.
"""
import errno
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs.base import get_config
from repro.core import packing, tierstore
from repro.core.schedule import ExecutionConfig
from repro.core.tierstore import (SegmentStore, TierIntegrityError,
                                  TierReadError, demote_plan, ring_depth)
from repro.optim import adam
from repro.testing import faults


def _cfg(n_layers=5):
    return get_config("bert-large", "smoke").replace(dtype="float32",
                                                     n_layers=n_layers)


def _segs(n=4, w=6, seed=0):
    rng = np.random.default_rng(seed)
    return {"float32": rng.standard_normal((n, w)).astype(np.float32),
            "bfloat16": np.arange(n * 3, dtype=np.float32).reshape(n, 3)
            .astype(jnp.bfloat16)}


def _assert_trees_bitwise(a, b, what):
    for i, (x, y) in enumerate(zip(jax.tree.leaves(a), jax.tree.leaves(b))):
        assert bool(jnp.all(x == y)), f"{what}: leaf {i} differs"


# ===========================================================================
# SegmentStore unit behavior
# ===========================================================================
def test_store_roundtrip_all_rows_and_slices(tmp_path):
    st = SegmentStore(str(tmp_path))
    segs = _segs()
    st.put("g0_w", segs, step=7)
    assert st.step("g0_w") == 7
    for lo, hi in [(0, 4), (1, 3), (2, 2), (3, 4)]:
        out = st.read_rows("g0_w", lo, hi)
        for k, arr in segs.items():
            got = out[k]
            assert got.dtype == np.asarray(arr).dtype
            np.testing.assert_array_equal(
                got.view(np.uint8), np.asarray(arr)[lo:hi].view(np.uint8))


def test_store_put_is_atomic_over_existing(tmp_path):
    """A re-put replaces the segment atomically; crash debris (a stale
    .tmp- staging dir) never shadows the committed data."""
    st = SegmentStore(str(tmp_path))
    st.put("g0_w", _segs(seed=1), step=1)
    new = _segs(seed=2)
    st.put("g0_w", new, step=2)
    # leftover staging debris from a "crashed" writer
    os.makedirs(str(tmp_path / (tierstore._TMP + "g0_w.999")))
    fresh = SegmentStore(str(tmp_path))
    assert fresh.step("g0_w") == 2
    np.testing.assert_array_equal(fresh.read_rows("g0_w", 0, 4)["float32"],
                                  new["float32"])


def test_store_open_detects_torn_write(tmp_path):
    """A truncated segment file (torn write under the final name — what
    the staged rename protocol prevents, simulated directly) fails the
    whole-file crc at OPEN, before any row is trusted."""
    st = SegmentStore(str(tmp_path))
    st.put("g0_w", _segs(), step=0)
    faults.corrupt_file(st.seg_path("g0_w", "float32"), mode="truncate")
    fresh = SegmentStore(str(tmp_path))   # no rebuilder attached
    with pytest.raises(TierIntegrityError, match="no rebuilder"):
        fresh.open("g0_w")
    assert fresh.metrics["quarantined"] == 1


def test_store_read_detects_in_place_rot(tmp_path):
    """A bit flipped AFTER open (manifest already cached and verified)
    is caught by the per-row crc at the read that returns it."""
    st = SegmentStore(str(tmp_path))
    st.put("g0_w", _segs(), step=0)
    st.open("g0_w")                       # cache the verified manifest
    faults.corrupt_segment(st, "g0_w", seg="float32", seed=3)
    with pytest.raises(TierIntegrityError, match="no rebuilder"):
        st.read_rows("g0_w", 0, 4)
    qdir = str(tmp_path / tierstore.QUARANTINE)
    assert os.listdir(qdir), "damaged segment must be quarantined, not lost"


def test_store_transient_eio_retries_then_recovers(tmp_path):
    st = SegmentStore(str(tmp_path), retries=3, backoff_s=0.001)
    st.put("g0_w", _segs(), step=0)
    fault = faults.inject_io_error(st, fail_reads=2, err=errno.EIO)
    out = st.read_rows("g0_w", 0, 4)
    np.testing.assert_array_equal(out["float32"], _segs()["float32"])
    assert fault.raised == 2
    assert st.metrics["retries"] >= 2


def test_store_persistent_eio_exhausts_budget(tmp_path):
    st = SegmentStore(str(tmp_path), retries=2, backoff_s=0.001)
    st.put("g0_w", _segs(), step=0)
    faults.inject_io_error(st, persistent=True)
    with pytest.raises(TierReadError, match="3 attempt"):
        st.read_rows("g0_w", 0, 4)


def test_store_nontransient_error_is_not_retried(tmp_path):
    st = SegmentStore(str(tmp_path), retries=5, backoff_s=0.001)
    st.put("g0_w", _segs(), step=0)
    faults.inject_io_error(st, persistent=True, err=errno.ENOSPC)
    with pytest.raises(TierReadError, match="1 attempt"):
        st.read_rows("g0_w", 0, 4)
    assert st.metrics["retries"] == 0


def test_store_rebuilder_heals_rot(tmp_path):
    """With a rebuilder attached, rot is quarantined, re-put from the
    authoritative source, and the original read succeeds."""
    st = SegmentStore(str(tmp_path))
    segs = _segs()
    st.put("g0_w", segs, step=0)
    st.open("g0_w")
    faults.corrupt_segment(st, "g0_w", seg="float32", seed=5)
    st.rebuilder = lambda key: st.put(key, segs, step=0)
    out = st.read_rows("g0_w", 0, 4)
    np.testing.assert_array_equal(out["float32"], segs["float32"])
    assert st.metrics["rebuilt_segments"] == 1
    assert st.metrics["quarantined"] == 1


# ===========================================================================
# Demotion plan + prefetch-ring watchdog arithmetic
# ===========================================================================
def test_demote_plan_budget_edges():
    assert demote_plan([10, 10], [4, 4], 0) == [0, 0]       # fully streamed
    assert demote_plan([10, 10], [4, 4], 1000) == [4, 4]    # all resident
    # coldest-first: the LAST group's tail demotes before group 0 is hit
    assert demote_plan([10, 10], [4, 4], 45) == [4, 0]
    assert demote_plan([10, 10], [4, 4], 55) == [4, 1]
    # demoting the whole last group is not enough -> walk into group 0
    assert demote_plan([10, 10], [4, 4], 25) == [2, 0]


def test_demote_plan_respects_budget_exactly():
    for budget in range(0, 90, 7):
        hot = demote_plan([8, 12], [5, 3], budget)
        resident = 8 * hot[0] + 12 * hot[1]
        assert resident <= max(budget, 0)
        # minimal demotion: one more hot row would break the budget
        if budget > 0 and hot != [5, 3]:
            gi = 1 if hot[1] < 3 else 0
            assert resident + [8, 12][gi] > budget


def test_ring_depth_watchdog():
    assert ring_depth(4, 10, 1000, True) == 4      # slack holds all 4
    assert ring_depth(4, 10, 25, True) == 2        # shrunk to fit
    assert ring_depth(4, 10, 0, True) == 1         # never below 1
    assert ring_depth(4, 10, 0, False) == 4        # unbounded budget
    assert ring_depth(0, 10, 5, True) == 1         # sequential floor


# ===========================================================================
# Bit-identity: tier chain vs host-only relay across the knob grid
# ===========================================================================
def _tier_exec(tmp_path, *, G=1, k=0, pk=False, K=1, budget=0, tiers=3):
    return ExecutionConfig(
        n_microbatches=2, layers_per_relay=G, prefetch_depth=k,
        pack_params=pk, stash_every=K, tiers=tiers,
        host_budget_bytes=budget, tier_dir=str(tmp_path), tier_backoff_s=0.001)


def _run_steps(eng, batch, n=2, hook=None):
    state = eng.init(jax.random.PRNGKey(0))
    m = {}
    for i in range(n):
        if hook is not None:
            hook(i, eng, state)
        state, m = eng.train_step(state, batch)
    if eng.tier is not None:
        state = eng.tier.stage_in(state)
    params, opt = state.params, state.legacy_opt()
    if eng.exec_cfg.pack_params:
        opt = packing.unpack_opt_state(opt, params)
        params = packing.unpack_params(params)
    return float(m["loss"]), params, opt


@pytest.mark.parametrize("name", ["l2l", "l2l-p"])
def test_tier_chain_bit_identical_across_grid(name, make_engine, tmp_path):
    """Grads/updates through the disk tier match the host-only relay
    bit-for-bit across {G} x {prefetch} x {pack} x {K}, both fully
    streamed (budget 0) and with a partial hot prefix (a budget that
    keeps ~2 layers resident)."""
    from repro import engine as engines
    cfg = _cfg()
    batch = make_batch(cfg, 4, 16)
    ref_eng = make_engine(name, optimizer=adam(lr=1e-3), cfg=cfg,
                          exec_cfg=ExecutionConfig(n_microbatches=2))
    ref = _run_steps(ref_eng, batch)
    # per-layer state (w + m + v) for this smoke config is ~1.6 MB: a
    # 4 MB budget keeps a 2-row hot prefix, exercising hot/cold concat
    grid = [(1, 0, False, 1, 0), (3, 2, True, 1, 0), (2, 1, False, 2, 0),
            (3, 0, True, 2, 0), (1, 2, True, 1, 4 << 20),
            (2, 0, False, 1, 4 << 20)]
    for G, k, pk, K, budget in grid:
        eng = make_engine(name, optimizer=adam(lr=1e-3), cfg=cfg,
                          exec_cfg=_tier_exec(tmp_path / f"g{G}k{k}{pk}{K}",
                                              G=G, k=k, pk=pk, K=K,
                                              budget=budget))
        got = _run_steps(eng, batch)
        tag = f"{name} G={G} k={k} pack={pk} K={K} budget={budget}"
        assert eng.tier.metrics["demoted_layers"] > 0, tag
        if budget:
            assert eng.tier.metrics["demoted_layers"] < cfg.n_layers, tag
        assert got[0] == ref[0], tag
        _assert_trees_bitwise(got[1], ref[1], f"{tag} params")
        _assert_trees_bitwise(got[2], ref[2], f"{tag} opt")


def test_tier_chain_bit_identical_with_forced_retry(make_engine, tmp_path):
    """A transient EIO burst mid-relay (within the retry budget) is
    absorbed: the run completes with bit-identical state and a nonzero
    retry count."""
    cfg = _cfg()
    batch = make_batch(cfg, 4, 16)
    ref = _run_steps(make_engine("l2l-p", optimizer=adam(lr=1e-3), cfg=cfg,
                                 exec_cfg=ExecutionConfig(n_microbatches=2)),
                     batch)
    eng = make_engine("l2l-p", optimizer=adam(lr=1e-3), cfg=cfg,
                      exec_cfg=_tier_exec(tmp_path, G=2, k=1, pk=True))

    def hook(i, eng, state):
        if i == 1:   # second step's stage_in hits the injected faults
            faults.inject_io_error(eng.tier.store, fail_reads=2)

    got = _run_steps(eng, batch, hook=hook)
    assert eng.tier.metrics["retries"] >= 2
    assert got[0] == ref[0]
    _assert_trees_bitwise(got[1], ref[1], "retry params")
    _assert_trees_bitwise(got[2], ref[2], "retry opt")


def test_tier_chain_quarantine_rebuild_mid_relay(make_engine, tmp_path):
    """Segment rot between steps is quarantined and rebuilt from the
    newest good checkpoint WITHOUT aborting the step loop, and the final
    state still matches the host-only run bit-for-bit."""
    cfg = _cfg()
    batch = make_batch(cfg, 4, 16)
    ref = _run_steps(make_engine("l2l-p", optimizer=adam(lr=1e-3), cfg=cfg,
                                 exec_cfg=ExecutionConfig(n_microbatches=2)),
                     batch, n=3)
    ckpt = str(tmp_path / "ckpt")
    eng = make_engine("l2l-p", optimizer=adam(lr=1e-3), cfg=cfg,
                      exec_cfg=_tier_exec(tmp_path / "store", pk=True))

    def hook(i, eng, state):
        eng.save(ckpt, state)            # step-matched rebuild source
        if i == 2:
            # the opt segments are re-read on every stage_in (the params
            # materialize-cache only covers the weight side), so rot here
            # is detected at the very next read
            faults.corrupt_segment(eng.tier.store, "g0_opt", seed=11)

    got = _run_steps(eng, batch, n=3, hook=hook)
    assert eng.tier.metrics["rebuilt_segments"] >= 1
    assert eng.tier.metrics["quarantined"] >= 1
    assert got[0] == ref[0]
    _assert_trees_bitwise(got[1], ref[1], "rebuild params")
    _assert_trees_bitwise(got[2], ref[2], "rebuild opt")


def test_tier_open_time_rebuild_from_checkpoint(make_engine, tmp_path):
    """Weight-segment rot that survives until a process restart is
    caught by the whole-file verification at OPEN and rebuilt from the
    newest good checkpoint — a fresh store over the same directory never
    serves the rotten bytes."""
    cfg = _cfg(n_layers=3)
    batch = make_batch(cfg, 4, 16)
    ckpt = str(tmp_path / "ckpt")
    eng = make_engine("l2l-p", optimizer=adam(lr=1e-3), cfg=cfg,
                      exec_cfg=_tier_exec(tmp_path / "store"))
    state = eng.init(jax.random.PRNGKey(0))
    state, _ = eng.train_step(state, batch)
    eng.save(ckpt, state)
    good = eng.tier.store.read_rows("g0_w", 0, 3)
    faults.corrupt_file(eng.tier.store.seg_path("g0_w", "float32"), seed=7)

    # "new process": a fresh store + chain over the same directory, with
    # the same checkpoint directory attached as the rebuild source
    store2 = SegmentStore(str(tmp_path / "store"))
    chain2 = tierstore.TierChain(store2)
    chain2._step = int(state.step)
    chain2.attach_checkpoints(ckpt, "ckpt", eng)
    store2.open("g0_w")                   # detect at open -> rebuild
    assert store2.metrics["rebuilt_segments"] == 1
    np.testing.assert_array_equal(store2.read_rows("g0_w", 0, 3)["float32"],
                                  good["float32"])


def test_tier_prefill_and_decode_bit_identical(make_engine, tmp_path):
    """Inference paths materialize demoted groups read-only (cached per
    staged-out state) and match the host-only engine exactly."""
    cfg = get_config("granite-3-8b", "smoke").replace(dtype="float32")
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    outs = {}
    for tiers in (2, 3):
        eng = make_engine("l2l", "granite-3-8b", cfg=cfg,
                          exec_cfg=_tier_exec(tmp_path / str(tiers), G=2,
                                              k=1, pk=True, tiers=tiers))
        state = eng.init(jax.random.PRNGKey(0))
        logits = eng.prefill(state, {"tokens": make_batch(cfg, 4, 16)[
            "tokens"]})
        caches, last = eng.decode_init(state, toks, live_seq=16)
        step_logits, _ = eng.decode_step(
            state, caches, jnp.argmax(last, -1)[:, None].astype(jnp.int32),
            jnp.int32(8))
        outs[tiers] = (logits, last, step_logits)
    for a, b in zip(outs[2], outs[3]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tier_checkpoints_interchange_with_host_only(make_engine, tmp_path):
    """A checkpoint saved from a tier-chain run restores into a host-only
    engine (and vice versa): the disk tier is invisible to the on-disk
    state layout, like every other relay knob."""
    cfg = _cfg(n_layers=3)
    batch = make_batch(cfg, 4, 16)
    tier_eng = make_engine("l2l-p", optimizer=adam(lr=1e-3), cfg=cfg,
                           exec_cfg=_tier_exec(tmp_path / "store"))
    state = tier_eng.init(jax.random.PRNGKey(0))
    state, _ = tier_eng.train_step(state, batch)
    tier_eng.save(str(tmp_path / "ck"), state)

    host_eng = make_engine("l2l-p", optimizer=adam(lr=1e-3), cfg=cfg,
                           exec_cfg=ExecutionConfig(n_microbatches=2))
    h_state, step = host_eng.restore(str(tmp_path / "ck"))
    assert step == 1
    full = tier_eng.tier.stage_in(state)
    _assert_trees_bitwise(h_state.params, full.params, "restored params")


# ===========================================================================
# Deliverable certification: >100B params under a 16 GiB device budget
# ===========================================================================
GiB = 1 << 30


@pytest.mark.parametrize("arch,shards,host_budget,k", [
    # qwen1.5-110b: 2.53 GiB/layer bf16 — the single-device paper-class
    # claim (110B > the paper's 50B): 4 transit slots fit 16 GiB HBM and
    # a 512 GiB host budget forces the cold tail to disk
    ("qwen1.5-110b", 1, 512 * GiB, 0),
    # grok-1-314b: 9.2 GiB/layer bf16 cannot fit 16 GiB unsharded (2
    # slots = 18.3 GiB) — certified at the production 16-way model
    # sharding (16x16 mesh), 64 GiB/host budget, disk carrying the rest
    ("grok-1-314b", 16, 64 * GiB, 2),
])
def test_tier_certifies_16gib_device(arch, shards, host_budget, k):
    from repro.core.memory_model import estimate
    from repro.models.model import LayeredModel
    model = LayeredModel(get_config(arch, "full"))
    rep = estimate(model, batch=8, seq=2048, n_microbatches=8,
                   mode="l2l_p", offload_stash=True, param_dtype_bytes=2,
                   prefetch_depth=k, layers_per_relay=1, stash_every=4,
                   pack_params=True, tiers=3, host_budget=host_budget,
                   model_shards=shards)
    assert rep.total_device <= 16 * GiB, \
        f"{arch}: device {rep.total_device / GiB:.2f} GiB > 16 GiB"
    assert rep.total_disk > 0, f"{arch}: nothing demoted to disk"
    assert rep.demoted_layers > 0
    assert rep.disk_reads > 0
    assert rep.disk_read_ahead_cap >= 1
    # the resident stacked state honors the host budget
    state_host = rep.params_host + rep.opt_state
    # opt_state includes the 1x grad transit term which demote_plan does
    # not manage; subtract it for the budget comparison
    grads = rep.params_host + rep.params_disk
    assert state_host - grads <= host_budget
