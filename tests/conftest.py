import jax
import jax.numpy as jnp
import pytest

# NOTE: no XLA_FLAGS here on purpose — unit tests and benches see ONE device.
# Multi-device dry-run tests spawn subprocesses (test_dryrun_small.py).


def make_batch(cfg, B, S, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.n_frames, cfg.d_model), dtype)
    if cfg.is_vlm:
        batch["patches"] = jax.random.normal(
            ks[3], (B, cfg.n_patches, cfg.vit_dim), dtype)
    return batch


@pytest.fixture(scope="session")
def archs():
    from repro.configs.base import list_archs
    return list_archs()


@pytest.fixture
def make_engine():
    """Factory for facade engines in tests.

    ``make_engine(name, arch=..., exec_cfg=..., optimizer=...)`` builds an
    Engine through the public registry with test-friendly defaults: smoke
    variant, float32 math, donation off (tests reuse states across calls).
    """
    from repro import engine as engines
    from repro.configs.base import get_config
    from repro.core.schedule import ExecutionConfig

    def _make(name, arch="bert-large", exec_cfg=None, *, variant="smoke",
              dtype="float32", optimizer=None, cfg=None, **kw):
        if cfg is None:
            cfg = get_config(arch, variant)
            if dtype:
                cfg = cfg.replace(dtype=dtype)
        kw.setdefault("donate", False)
        return engines.create(name, cfg,
                              exec_cfg or ExecutionConfig(n_microbatches=2),
                              optimizer=optimizer, **kw)

    return _make
