"""Optimizer unit tests against hand-computed recurrences."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adam, adamw, lamb, sgd, make_schedule


def test_adam_matches_numpy():
    opt = adam(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    s = opt.init(p)
    g = {"w": jnp.array([0.5, 0.5, -1.0])}
    pn, sn = opt.update(g, s, p, jnp.int32(0))
    m = 0.1 * np.array([0.5, 0.5, -1.0])
    v = 0.01 * np.array([0.25, 0.25, 1.0])
    a = 1e-2 * np.sqrt(1 - 0.99) / (1 - 0.9)
    expect = np.array([1.0, -2.0, 3.0]) - a * m / (np.sqrt(v) + 1e-8)
    np.testing.assert_allclose(np.asarray(pn["w"]), expect, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(sn["w"]["m"]), m, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(sn["w"]["v"]), v, rtol=1e-6)


def test_adamw_decay():
    opt = adamw(lr=1e-2, weight_decay=0.1)
    p = {"w": jnp.ones(3) * 10}
    s = opt.init(p)
    g = {"w": jnp.zeros(3)}
    pn, _ = opt.update(g, s, p, jnp.int32(0))
    # zero grad -> pure decay: p - lr_corr * wd * p
    assert float(pn["w"][0]) < 10.0


def test_lamb_trust_ratio_scaling():
    opt = lamb(lr=1.0, weight_decay=0.0)
    p = {"w": jnp.ones(4) * 2.0}
    s = opt.init(p)
    g = {"w": jnp.ones(4) * 1000.0}
    pn, _ = opt.update(g, s, p, jnp.int32(0))
    # huge gradient, but trust ratio normalizes the update to ~|w|
    delta = float(jnp.max(jnp.abs(pn["w"] - p["w"])))
    assert delta < 10.0


def test_sgd_momentum():
    opt = sgd(lr=0.1, momentum=0.9)
    p = {"w": jnp.zeros(2)}
    s = opt.init(p)
    g = {"w": jnp.ones(2)}
    p1, s1 = opt.update(g, s, p, jnp.int32(0))
    p2, s2 = opt.update(g, s1, p1, jnp.int32(1))
    np.testing.assert_allclose(np.asarray(p1["w"]), -0.1, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(p2["w"]), -0.1 - 0.19, rtol=1e-5)


def test_schedule_warmup_cosine():
    sched = make_schedule(1.0, warmup=10, total=110, kind="cosine")
    assert float(sched(jnp.int32(0))) < 0.2
    assert abs(float(sched(jnp.int32(9))) - 1.0) < 1e-6
    assert float(sched(jnp.int32(109))) < 0.01


def test_stacked_layer_update_matches_per_layer():
    """Updating a stacked (N, ...) tree at once == per-layer updates —
    the eager L2L path relies on this."""
    opt = adam(lr=1e-3)
    N = 3
    ps = {"w": jax.random.normal(jax.random.PRNGKey(0), (N, 4, 4))}
    gs = {"w": jax.random.normal(jax.random.PRNGKey(1), (N, 4, 4))}
    s = opt.init(ps)
    pn, _ = opt.update(gs, s, ps, jnp.int32(0))
    for i in range(N):
        pi = {"w": ps["w"][i]}
        gi = {"w": gs["w"][i]}
        si = opt.init(pi)
        pni, _ = opt.update(gi, si, pi, jnp.int32(0))
        np.testing.assert_allclose(np.asarray(pn["w"][i]),
                                   np.asarray(pni["w"]), rtol=1e-6)
