"""Unit tests for the logical-axis sharding rules (divisibility fallbacks,
double-use protection, decode cache layout)."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import get_config
from repro.distributed import sharding as shd
from repro.models.model import LayeredModel


def mesh44():
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    # 1-device "mesh" with logical shape (1,1) is enough for rule logic;
    # axis sizes come from the mesh shape we declare.
    return Mesh(np.asarray(devs[:1]).reshape(1, 1), ("data", "model"))


class FakeMesh:
    """Shape-only stand-in (the rules never touch devices)."""
    def __init__(self, **shape):
        self.shape = shape


def test_heads_divisibility_fallback():
    cfg = get_config("hymba-1.5b")       # 25 heads: not divisible by 16
    rules = shd.make_rules(cfg, FakeMesh(data=16, model=16))
    assert rules["heads"] is None
    assert rules["ffn"] == "model"        # 5504 % 16 == 0
    cfg2 = get_config("command-r-35b")    # 64 heads
    rules2 = shd.make_rules(cfg2, FakeMesh(data=16, model=16))
    assert rules2["heads"] == "model"


def test_vocab_divisibility():
    cfg = get_config("whisper-base")      # 51865: indivisible
    rules = shd.make_rules(cfg, FakeMesh(data=16, model=16))
    assert rules["vocab"] is None
    cfg2 = get_config("qwen1.5-110b")     # 152064
    assert shd.make_rules(cfg2, FakeMesh(data=16, model=16))["vocab"] \
        == "model"


def test_expert_vs_tp_sharding():
    ds = get_config("deepseek-v2-lite-16b")   # 64 experts
    r = shd.make_rules(ds, FakeMesh(data=16, model=16))
    assert r["experts"] == "model" and r["expert_ffn"] is None
    gk = get_config("grok-1-314b")            # 8 experts < 16
    r = shd.make_rules(gk, FakeMesh(data=16, model=16))
    assert r["experts"] is None and r["expert_ffn"] == "model"


def test_decode_rules_shard_cache_seq():
    cfg = get_config("granite-3-8b")
    r = shd.make_rules(cfg, FakeMesh(pod=2, data=16, model=16),
                       kind="decode", batch_size=128)
    assert r["seq"] == "model"
    assert r["kv"] is None                 # can't double-use the axis
    assert r["batch"] == ("pod", "data")


def test_batch_indivisible_goes_replicated():
    cfg = get_config("granite-3-8b")
    r = shd.make_rules(cfg, FakeMesh(data=16, model=16), kind="decode",
                       batch_size=1)       # long_500k
    assert r["batch"] is None


def test_spec_to_pspec_no_axis_double_use():
    rules = {"a": "model", "b": "model", "c": ("pod", "data")}
    ps = shd.spec_to_pspec(("a", "b", "c"), rules)
    assert ps == P("model", None, ("pod", "data"))


def test_spec_to_pspec_shape_divisibility():
    rules = {"seq": "model"}
    ps = shd.spec_to_pspec(("seq",), rules, shape=(1500,),
                           mesh=FakeMesh(model=16))
    assert ps == P()                       # 1500 % 16 != 0 -> replicate
    ps2 = shd.spec_to_pspec(("seq",), rules, shape=(1600,),
                            mesh=FakeMesh(model=16))
    assert ps2 == P("model")


def test_param_pspecs_cover_all_leaves():
    for arch in ("deepseek-v2-lite-16b", "whisper-base", "rwkv6-1.6b"):
        cfg = get_config(arch)
        model = LayeredModel(cfg)
        rules = shd.make_rules(cfg, FakeMesh(data=16, model=16))
        slices = shd.layer_slice_pspecs(model, None, rules)
        for tree in slices:
            for p in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, P)):
                assert isinstance(p, P)
