"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("shape", [(1, 2, 128, 64), (2, 4, 256, 128),
                                   (1, 1, 512, 64)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_oracle(shape, causal, window, dtype):
    B, H, S, D = shape
    ks = jax.random.split(jax.random.PRNGKey(S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, H, D), dtype)
    v = jax.random.normal(ks[2], (B, S, H, D), dtype)
    o = ops.flash_attention(q, k, v, causal=causal, window=window,
                            block_q=64, block_k=64)
    t = lambda x: x.transpose(0, 2, 1, 3)
    r = t(ref.ref_attention(t(q), t(k), t(v), causal=causal, window=window))
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    err = float(jnp.max(jnp.abs(o.astype(jnp.float32)
                                - r.astype(jnp.float32))))
    assert err < tol, err


def test_flash_attention_soft_cap():
    B, H, S, D = 1, 2, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (B, S, H, D)) * 3 for kk in ks)
    o = ops.flash_attention(q, k, v, causal=True, soft_cap=30.0,
                            block_q=64, block_k=64)
    t = lambda x: x.transpose(0, 2, 1, 3)
    r = t(ref.ref_attention(t(q), t(k), t(v), causal=True, soft_cap=30.0))
    assert float(jnp.max(jnp.abs(o - r))) < 2e-5


def test_flash_attention_matches_model_attend():
    """Kernel == the chunked jnp attention used by the models."""
    from repro.models.attention import attend
    B, H, S, D = 1, 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    a = attend(q, k, v, pos, pos, causal=True, chunk=64)
    o = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    assert float(jnp.max(jnp.abs(a - o))) < 2e-5


@pytest.mark.parametrize("n", [128, 1000, 4096, 100_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_adam_vs_oracle(n, dtype):
    ks = jax.random.split(jax.random.PRNGKey(n), 4)
    p = jax.random.normal(ks[0], (n,), dtype)
    g = jax.random.normal(ks[1], (n,), jnp.float32)
    m = jax.random.normal(ks[2], (n,), jnp.float32) * 0.1
    v = jnp.abs(jax.random.normal(ks[3], (n,), jnp.float32)) * 0.01
    p2, m2, v2 = ops.fused_adam(p, g, m, v, 1e-3, 0.7, wd=0.01)
    rp, rm, rv = ref.ref_adam(p, g, m, v, 1e-3, 0.7, wd=0.01)
    assert jnp.allclose(m2, rm, atol=1e-6)
    assert jnp.allclose(v2, rv, atol=1e-6)
    assert jnp.allclose(p2.astype(jnp.float32), rp.astype(jnp.float32),
                        atol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


@pytest.mark.parametrize("shape", [(4, 128), (37, 256), (2, 8, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_vs_oracle(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],), jnp.float32)
    o = ops.rmsnorm(x, s)
    r = ref.ref_rmsnorm(x, s)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    assert float(jnp.max(jnp.abs(o.astype(jnp.float32)
                                 - r.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 32])
def test_flash_attention_vjp_vs_oracle(causal, window):
    """FA-2 recompute backward (dq/dk/dv Pallas kernels) == autodiff of
    the naive oracle."""
    B, H, S, D = 1, 2, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q, k, v, seed = (jax.random.normal(kk, (B, S, H, D)) for kk in ks)
    t = lambda x: x.transpose(0, 2, 1, 3)

    def f_kernel(q, k, v):
        return (ops.flash_attention(q, k, v, causal=causal, window=window,
                                    block_q=64, block_k=64) * seed).sum()

    def f_ref(q, k, v):
        return (t(ref.ref_attention(t(q), t(k), t(v), causal=causal,
                                    window=window)) * seed).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        assert float(jnp.max(jnp.abs(a - b))) < 5e-5


def test_use_pallas_training_path_matches_jnp():
    """End-to-end: a smoke model trained with cfg.use_pallas computes the
    same L2L gradients as the jnp chunked-attention path."""
    from conftest import make_batch
    from repro import engine as engines
    from repro.configs.base import get_config
    from repro.core.schedule import ExecutionConfig
    cfg0 = get_config("granite-3-8b", "smoke").replace(
        dtype="float32", max_seq_len=64)
    cfg1 = cfg0.replace(use_pallas=True)
    ec = ExecutionConfig(n_microbatches=1)
    e0 = engines.create("l2l", cfg0, ec, donate=False)
    e1 = engines.create("l2l", cfg1, ec, donate=False)
    params = e0.model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg0, 2, 64)
    l0, g0 = e0.grads(params, batch)
    l1, g1 = e1.grads(params, batch)
    assert abs(float(l0) - float(l1)) < 1e-4
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g0, g1)))
    assert err < 1e-3, err


def test_rmsnorm_matches_model_norm():
    from repro.models.common import apply_norm
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 128))
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (128,))) + 0.5
    o = ops.rmsnorm(x, s)
    r = apply_norm({"scale": s}, x)
    assert float(jnp.max(jnp.abs(o - r))) < 1e-5


def test_apply_norm_pallas_gate_parity():
    """The flag-gated fused RMSNorm in models/common.apply_norm matches
    the jnp reference — forward AND gradients (custom VJP: Pallas forward,
    reference-recompute backward) — and the layernorm branch ignores the
    flag."""
    from repro.models.common import apply_norm, use_pallas_rmsnorm
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 17, 96))
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(5), (96,))) + 0.5
    seed = jax.random.normal(jax.random.PRNGKey(6), x.shape)

    def loss(xx, ss, w_extra=None):
        w = {"scale": ss} if w_extra is None else {"scale": ss, **w_extra}
        return (apply_norm(w, xx) * seed).sum()

    ref_o = apply_norm({"scale": s}, x)
    ref_g = jax.grad(loss, argnums=(0, 1))(x, s)
    prev = use_pallas_rmsnorm(True)
    try:
        fused_o = apply_norm({"scale": s}, x)
        fused_g = jax.grad(loss, argnums=(0, 1))(x, s)
        # layernorm branch must not dispatch to the rmsnorm kernel
        ln_w = {"scale": s, "bias": jnp.zeros((96,))}
        ln = apply_norm(ln_w, x)
    finally:
        use_pallas_rmsnorm(prev)
    assert float(jnp.max(jnp.abs(fused_o - ref_o))) < 1e-5
    for a, b in zip(fused_g, ref_g):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-5
    assert float(jnp.max(jnp.abs(ln - apply_norm(ln_w, x)))) == 0.0


def test_apply_norm_pallas_gate_end_to_end_grads():
    """A smoke L2L training-gradient pass with the fused RMSNorm enabled
    matches the jnp-norm gradients (the gate is safe under jax.vjp)."""
    from conftest import make_batch
    from repro import engine as engines
    from repro.configs.base import get_config
    from repro.core.schedule import ExecutionConfig
    from repro.models.common import use_pallas_rmsnorm
    cfg = get_config("granite-3-8b", "smoke").replace(
        dtype="float32", max_seq_len=64)
    ec = ExecutionConfig(n_microbatches=1)
    eng0 = engines.create("l2l", cfg, ec, donate=False)
    params = eng0.model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 32)
    l0, g0 = eng0.grads(params, batch)
    prev = use_pallas_rmsnorm(True)
    try:
        eng1 = engines.create("l2l", cfg, ec, donate=False)
        l1, g1 = eng1.grads(params, batch)
    finally:
        use_pallas_rmsnorm(prev)
    assert abs(float(l0) - float(l1)) < 1e-4
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g0, g1)))
    assert err < 1e-3, err


# ===========================================================================
# interpret-mode parity: the CPU-interpret kernels ARE the jnp chains
# ===========================================================================
# The transport kernel's CI story rests on interpret mode being a faithful
# stand-in for the compiled kernel math, so pin the two fused kernels to
# the exact jnp op chains their bodies execute: bitwise where every op is
# an elementwise f32 chain, tight tol anywhere an implementation is free
# to reassociate.
from functools import partial

import numpy as np

from repro.kernels.fused_adam import fused_adam_flat
from repro.kernels.rmsnorm import rmsnorm_2d


# both chains are jitted: interpret-mode pallas lowers the kernel body
# through XLA, so the reference must too — eager jnp skips fusion
# (no FMA contraction) and drifts by a few f32 ulps
@partial(jax.jit, static_argnames=("b1", "b2", "eps", "wd", "wd_form"))
def _adam_chain(p, g, m, v, a, clip, *, b1, b2, eps, wd, wd_form):
    """The _adam_kernel body, written in plain jnp."""
    g = g.astype(jnp.float32) * clip
    m2 = b1 * m + (1.0 - b1) * g
    v2 = b2 * v + (1.0 - b2) * g * g
    p32 = p.astype(jnp.float32)
    if wd_form:
        p2 = p32 - a * (m2 / (jnp.sqrt(v2) + eps) + wd * p32)
    else:
        p2 = p32 - a * m2 / (jnp.sqrt(v2) + eps)
    return p2.astype(p.dtype), m2, v2


@pytest.mark.parametrize("n,block", [(128, 16384), (1024, 256),
                                     (16384, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("wd_form", [False, True])
def test_fused_adam_flat_interpret_bitwise(n, block, dtype, wd_form):
    ks = jax.random.split(jax.random.PRNGKey(n + wd_form), 4)
    p = jax.random.normal(ks[0], (n,), dtype)
    g = jax.random.normal(ks[1], (n,), jnp.float32)
    m = jax.random.normal(ks[2], (n,), jnp.float32) * 0.1
    v = jnp.abs(jax.random.normal(ks[3], (n,), jnp.float32)) * 0.01
    a, clip = jnp.float32(1e-3), jnp.float32(0.7)
    wd = 0.01 if wd_form else 0.0
    outs = fused_adam_flat(p, g, m, v, a, clip, wd=wd, wd_form=wd_form,
                           block=block, interpret=True)
    refs = _adam_chain(p, g, m, v, a, clip, b1=0.9, b2=0.999, eps=1e-8,
                       wd=wd, wd_form=wd_form)
    # pure elementwise f32 chain: interpret mode must be bit-exact in
    # every dtype, including the final bf16 round of p'
    for o, r in zip(outs, refs):
        assert np.array_equal(np.asarray(o), np.asarray(r)), (n, dtype)


@partial(jax.jit, static_argnames=("eps",))
def _rmsnorm_chain(x, s, eps):
    """The _rmsnorm_kernel body, written in plain jnp."""
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(ms + eps)
            * s.astype(jnp.float32)).astype(x.dtype)


@pytest.mark.parametrize("shape,block_rows", [((4, 128), 256),
                                              ((64, 512), 16),
                                              ((1024, 64), 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_2d_interpret_parity(shape, block_rows, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],), jnp.float32)
    o = rmsnorm_2d(x, s, block_rows=block_rows, interpret=True)
    r = _rmsnorm_chain(x, s, 1e-6)
    if np.array_equal(np.asarray(o), np.asarray(r)):
        return
    # the row-mean reduction may legally reassociate between the tiled
    # kernel and the whole-array chain; anything beyond a few ulps of
    # f32 accumulation is a real bug
    err = float(jnp.max(jnp.abs(o.astype(jnp.float32)
                                - r.astype(jnp.float32))))
    assert err < (1e-6 if dtype == jnp.float32 else 1e-2), err
