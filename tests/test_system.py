"""End-to-end behaviour tests: training converges, engines interchange,
serving generates, the drivers run — all through the Engine facade."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import make_batch
from repro import engine as engines
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.optim import adam, make_schedule


def _train(engine, steps=25, seed=0, dtype=None):
    cfg = get_config("bert-large", "smoke")
    if dtype:
        cfg = cfg.replace(dtype=dtype)
    opt = adam(lr=3e-3, schedule=make_schedule(3e-3, warmup=5))
    eng = engines.create(engine, cfg, ExecutionConfig(n_microbatches=2),
                         optimizer=opt, donate=False)
    state = eng.init(jax.random.PRNGKey(seed))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=8, seed=seed))
    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, m = eng.train_step(state, b)
        losses.append(float(m["loss"]))
    return losses


def test_l2l_training_converges():
    losses = _train("l2l-p", steps=30)
    assert losses[-1] < losses[0] - 0.15, losses[::6]
    assert all(np.isfinite(losses))


def test_l2l_and_baseline_learning_curves_match():
    """Fig 3/4's claim, in miniature: the L2L-p and baseline curves
    coincide step-for-step.

    Run in float32 with a two-tier tolerance: the schedules compute the
    same math but not the same fp-reassociation order, and Adam's
    cold-start (bias-corrected update ~ lr*sign(g) while v is tiny)
    amplifies last-ulp gradient differences chaotically — ~10-30x per
    step (measured; same phenomenon noted in benchmarks/
    table3_convergence.py).  Early steps are asserted tight (any
    systematic schedule bug — wrong lr step, missing aux, bad
    normalization — shows up at >1e-3 immediately); the full horizon
    gets the chaos-scaled bound.  Exact per-step gradient/update
    identity is pinned separately in tests/test_equivalence.py and
    tests/test_prefetch.py."""
    l1 = _train("l2l-p", steps=8, dtype="float32")
    l2 = _train("baseline", steps=8, dtype="float32")
    np.testing.assert_allclose(l1[:4], l2[:4], rtol=2e-3)
    np.testing.assert_allclose(l1, l2, rtol=5e-2)


def test_serving_generates_tokens(make_engine):
    eng = make_engine("l2l", "granite-3-8b", dtype=None,
                      exec_cfg=ExecutionConfig())
    cfg = eng.model.cfg
    params = eng.model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    caches, logits = eng.decode_init(params, toks, live_seq=24)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outs = []
    for i in range(6):
        logits, caches = eng.decode_step(params, caches, tok,
                                         jnp.int32(8 + i))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        outs.append(tok)
    toks_out = jnp.concatenate(outs, 1)
    assert toks_out.shape == (2, 6)
    assert bool((toks_out >= 0).all())


def test_train_driver_cli():
    from repro.launch.train import main
    losses = main(["--arch", "bert-large", "--variant", "smoke",
                   "--steps", "6", "--batch", "8", "--seq", "32",
                   "--ub", "2", "--log-every", "5"])
    assert len(losses) == 6 and np.isfinite(losses).all()


def test_serve_driver_cli():
    from repro.launch.serve import main
    # default mode is continuous batching: returns the completed requests
    reqs = main(["--arch", "rwkv6-1.6b", "--variant", "smoke",
                 "--requests", "3", "--max-batch", "2",
                 "--prompt-len", "8", "--gen", "4"])
    assert len(reqs) == 3
    assert all(r.done and len(r.generated) == 4 for r in reqs)
    # legacy fixed-batch path stays available under --mode oneshot
    toks = main(["--arch", "rwkv6-1.6b", "--variant", "smoke",
                 "--mode", "oneshot",
                 "--batch", "2", "--prompt-len", "8", "--gen", "4"])
    assert toks.shape == (2, 4)


def test_host_optimizer_matches_device_optimizer(make_engine):
    """The EPS-host optimizer (compute_on 'device_host' — the paper's CPU
    optimizer) produces identical updates."""
    cfg = get_config("bert-large", "smoke").replace(dtype="float32")
    batch = make_batch(cfg, 4, 16)
    outs = {}
    for host in (False, True):
        eng = make_engine("l2l-p", optimizer=adam(lr=1e-3),
                          exec_cfg=ExecutionConfig(n_microbatches=2,
                                                   host_optimizer=host))
        state, m = eng.train_step(eng.init(jax.random.PRNGKey(0)), batch)
        outs[host] = (state.params, float(m["loss"]))
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        outs[False][0], outs[True][0])))
    assert err < 1e-6
    assert outs[False][1] == outs[True][1]


def test_weight_stream_flag_is_noop_on_cpu(make_engine):
    """weight_stream placements degrade gracefully off-TPU but the step
    still runs and matches the non-streamed result."""
    cfg = get_config("bert-large", "smoke").replace(dtype="float32")
    batch = make_batch(cfg, 4, 16)
    e1 = make_engine("l2l", exec_cfg=ExecutionConfig(n_microbatches=2))
    e2 = make_engine("l2l", exec_cfg=ExecutionConfig(
        n_microbatches=2, weight_stream=True, offload_stash=True))
    params = e1.model.init_params(jax.random.PRNGKey(0))
    _, g1 = e1.grads(params, batch)
    _, g2 = e2.grads(params, batch)
    errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), g1, g2)
    assert max(jax.tree.leaves(errs)) < 1e-5
