"""End-to-end behaviour tests: training converges, engines interchange,
serving generates, the drivers run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs.base import get_config
from repro.core import baseline, decode as dec, l2l
from repro.core.schedule import ExecutionConfig
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models.model import LayeredModel
from repro.optim import adam, make_schedule


def _train(engine, steps=25, seed=0):
    cfg = get_config("bert-large", "smoke")
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    opt = adam(lr=3e-3, schedule=make_schedule(3e-3, warmup=5))
    ec = ExecutionConfig(n_microbatches=2)
    if engine == "l2l":
        step = jax.jit(l2l.make_train_step(model, opt, ec))
        st = l2l.init_opt_state(opt, params)
    else:
        step = jax.jit(baseline.make_train_step(model, opt, ec))
        st = baseline.init_opt_state(opt, params)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=8, seed=seed))
    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, st, m = step(params, st, b)
        losses.append(float(m["loss"]))
    return losses


def test_l2l_training_converges():
    losses = _train("l2l", steps=30)
    assert losses[-1] < losses[0] - 0.15, losses[::6]
    assert all(np.isfinite(losses))


def test_l2l_and_baseline_learning_curves_match():
    """Fig 3/4's claim, in miniature: identical losses step-for-step."""
    l1 = _train("l2l", steps=8)
    l2 = _train("baseline", steps=8)
    np.testing.assert_allclose(l1, l2, rtol=2e-3)


def test_serving_generates_tokens():
    cfg = get_config("granite-3-8b", "smoke")
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    caches, logits = dec.prefill(model, params, toks, live_seq=24)
    serve = jax.jit(dec.make_serve_step(model, ExecutionConfig()))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outs = []
    for i in range(6):
        logits, caches = serve(params, caches, tok, jnp.int32(8 + i))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        outs.append(tok)
    toks_out = jnp.concatenate(outs, 1)
    assert toks_out.shape == (2, 6)
    assert bool((toks_out >= 0).all())


def test_train_driver_cli():
    from repro.launch.train import main
    losses = main(["--arch", "bert-large", "--variant", "smoke",
                   "--steps", "6", "--batch", "8", "--seq", "32",
                   "--ub", "2", "--log-every", "5"])
    assert len(losses) == 6 and np.isfinite(losses).all()


def test_serve_driver_cli():
    from repro.launch.serve import main
    toks = main(["--arch", "rwkv6-1.6b", "--variant", "smoke",
                 "--batch", "2", "--prompt-len", "8", "--gen", "4"])
    assert toks.shape == (2, 4)


def test_host_optimizer_matches_device_optimizer():
    """The EPS-host optimizer (compute_on 'device_host' — the paper's CPU
    optimizer) produces identical updates."""
    cfg = get_config("bert-large", "smoke").replace(dtype="float32")
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 16)
    opt = adam(lr=1e-3)
    outs = {}
    for host in (False, True):
        step = jax.jit(l2l.make_train_step(
            model, opt, ExecutionConfig(n_microbatches=2,
                                        host_optimizer=host)))
        st = l2l.init_opt_state(opt, params)
        p, _, m = step(params, st, batch)
        outs[host] = (p, float(m["loss"]))
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        outs[False][0], outs[True][0])))
    assert err < 1e-6
    assert outs[False][1] == outs[True][1]


def test_weight_stream_flag_is_noop_on_cpu():
    """weight_stream placements degrade gracefully off-TPU but the step
    still runs and matches the non-streamed result."""
    cfg = get_config("bert-large", "smoke").replace(dtype="float32")
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 16)
    _, g1 = jax.jit(l2l.make_grads_fn(
        model, ExecutionConfig(n_microbatches=2)))(params, batch)
    _, g2 = jax.jit(l2l.make_grads_fn(
        model, ExecutionConfig(n_microbatches=2, weight_stream=True,
                               offload_stash=True)))(params, batch)
    errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), g1, g2)
    assert max(jax.tree.leaves(errs)) < 1e-5
