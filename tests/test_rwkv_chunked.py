"""Chunked-parallel WKV6 (beyond-paper prefill optimization) must equal
the step-by-step recurrence in both forward and gradients."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.models.model import LayeredModel


def _setup(chunk):
    cfg = get_config("rwkv6-1.6b", "smoke").replace(dtype="float32",
                                                    rwkv_chunk=chunk)
    return LayeredModel(cfg)


def _batch(cfg, B, S, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    t = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    return {"tokens": t, "targets": t, "mask": jnp.ones((B, S), jnp.float32)}


@pytest.mark.parametrize("S,chunk", [(64, 16), (64, 32), (96, 16)])
def test_chunked_wkv_forward(S, chunk):
    m0, m1 = _setup(0), _setup(chunk)
    params = m0.init_params(jax.random.PRNGKey(0))
    batch = _batch(m0.cfg, 2, S)
    l0, _ = jax.jit(lambda p, b: m0.full_loss(p, b))(params, batch)
    l1, _ = jax.jit(lambda p, b: m1.full_loss(p, b))(params, batch)
    assert abs(float(l0) - float(l1)) < 1e-4


def test_chunked_wkv_gradients():
    m0, m1 = _setup(0), _setup(16)
    params = m0.init_params(jax.random.PRNGKey(0))
    batch = _batch(m0.cfg, 2, 64)
    g0 = jax.jit(jax.grad(lambda p: m0.full_loss(p, batch)[0]))(params)
    g1 = jax.jit(jax.grad(lambda p: m1.full_loss(p, batch)[0]))(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        diff = float(jnp.max(jnp.abs(a - b)))
        scale = float(jnp.max(jnp.abs(a))) + 1e-9
        assert diff / scale < 1e-3


def test_chunked_wkv_nonmultiple_falls_back():
    """seq not divisible by chunk: silently use the step scan."""
    m1 = _setup(16)
    params = m1.init_params(jax.random.PRNGKey(0))
    batch = _batch(m1.cfg, 2, 50)
    l1, _ = jax.jit(lambda p, b: m1.full_loss(p, b))(params, batch)
    assert jnp.isfinite(l1)
