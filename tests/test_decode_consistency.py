"""Decode-vs-train consistency: feeding a sequence token-by-token through
``serve_step`` (KV caches / ring buffers / MLA compression / SSM states)
must reproduce the full-forward logits at the last position.  This pins
every cache code path against the training path."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config, list_archs
from repro.core import decode as dec
from repro.core.schedule import ExecutionConfig
from repro.models.model import LayeredModel

ARCHS = list_archs()


def full_forward_logits(model, params, batch):
    """Last-position logits from the training-style full forward."""
    static = {"embed": params["embed"], "head": params["head"]}
    x, mem = model.prepare(static, batch)
    for gi, group in enumerate(model.groups):
        if gi > 0:
            x, mem = model.transition(gi, static, x, batch)
        ctx = model.train_ctx(batch, group)
        def body(h, w, _g=group, _m=mem, _c=ctx):
            h2, _ = _g.apply(w, h, _m, _c)
            return h2, None
        x, _ = jax.lax.scan(body, x, params["groups"][gi])
    return model.decode_logits(static, x[:, -1:, :])[:, 0]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch, "smoke").replace(dtype="float32")
    if cfg.is_vlm:
        cfg = cfg.replace(is_vlm=False, name=cfg.name + "-lm")  # LM backbone
    if cfg.n_experts:
        # ample capacity: the full-forward capacity path must not drop
        # tokens that the decode dense path computes exactly
        cfg = cfg.replace(capacity_factor=100.0)
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks,
             "mask": jnp.ones((B, S), jnp.float32)}
    frames = None
    if cfg.family == "audio":
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.n_frames, cfg.d_model),
                                   jnp.float32)
        batch["frames"] = frames
    ref = full_forward_logits(model, params, batch)
    _, last = dec.prefill(model, params, toks, live_seq=S, frames=frames)
    err = float(jnp.max(jnp.abs(ref - last)))
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert err / scale < 2e-3, f"{arch}: rel err {err/scale:.2e}"


def test_ring_buffer_window_decode():
    """Long-context mode: a ring buffer of `window` slots must reproduce
    sliding-window attention computed over the full sequence."""
    cfg = get_config("granite-3-8b", "smoke").replace(
        dtype="float32", sliding_window=8, attn_chunk=0)
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S, W = 1, 24, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks,
             "mask": jnp.ones((B, S), jnp.float32)}
    ref = full_forward_logits(model, params, batch)
    # ring buffer of only W slots
    ec = ExecutionConfig(decode_window=W)
    _, last = dec.prefill(model, params, toks, live_seq=W, exec_cfg=ec)
    err = float(jnp.max(jnp.abs(ref - last)))
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert err / scale < 2e-3, err / scale


def test_mla_absorbed_decode_matches_naive():
    """DeepSeek MLA: the absorbed-matmul decode path must equal expanding
    the compressed cache to full K/V (the train-path math).

    Ample expert capacity for the reference forward: deepseek-v2-lite is
    MoE, and at the default capacity_factor the train-path dispatch drops
    tokens that the decode-path dense routing computes exactly — that
    (orthogonal) difference would drown the MLA comparison this test pins
    (isolated, the absorbed and naive paths agree to ~1e-6)."""
    cfg = get_config("deepseek-v2-lite-16b", "smoke").replace(
        dtype="float32", capacity_factor=100.0)
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks,
             "mask": jnp.ones((B, S), jnp.float32)}
    ref = full_forward_logits(model, params, batch)
    _, last = dec.prefill(model, params, toks, live_seq=S)
    err = float(jnp.max(jnp.abs(ref - last)))
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert err / scale < 2e-3
