"""Double-buffered relay (ExecutionConfig.prefetch_depth) invariants.

The prefetch restructuring moves the per-layer weight fetch out of the
consuming scan iteration and into the previous one (carried HBM slot).
That must be a pure SCHEDULE change: depth 1 computes bit-identical
gradients, updates, prefill logits and decode steps to depth 0 for every
L2L schedule — and the analytic memory model must charge the second layer
slot (the paper's "the executing layer(s)'s footprint", plural)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro import engine as engines
from repro.configs.base import get_config
from repro.core.memory_model import estimate
from repro.core.schedule import ExecutionConfig
from repro.models.model import LayeredModel
from repro.optim import adam


def _cfg(arch="bert-large"):
    return get_config(arch, "smoke").replace(dtype="float32")


def _assert_trees_bitwise(a, b, what):
    mismatched = [
        k for k, (x, y) in enumerate(zip(jax.tree.leaves(a),
                                         jax.tree.leaves(b)))
        if not bool(jnp.all(x == y))]
    assert not mismatched, f"{what}: leaves {mismatched} differ"


@pytest.mark.parametrize("name", ["l2l", "l2l-p"])
def test_prefetch_grads_bit_identical(name, make_engine):
    cfg = _cfg()
    batch = make_batch(cfg, 4, 16)
    params = LayeredModel(cfg).init_params(jax.random.PRNGKey(0))
    outs = {}
    for pf in (0, 1):
        eng = make_engine(name, exec_cfg=ExecutionConfig(
            n_microbatches=2, prefetch_depth=pf))
        outs[pf] = eng.grads(params, batch)
    assert float(outs[0][0]) == float(outs[1][0])
    _assert_trees_bitwise(outs[0][1], outs[1][1], f"{name} grads")


@pytest.mark.parametrize("name", ["l2l", "l2l-p"])
def test_prefetch_updates_bit_identical(name, make_engine):
    """Full train step: the trailing (Alg 3) and eager (Alg 4) optimizer
    relays must produce bit-identical new params AND opt state."""
    cfg = _cfg()
    batch = make_batch(cfg, 4, 16)
    states = {}
    for pf in (0, 1):
        eng = make_engine(name, optimizer=adam(lr=1e-3),
                          exec_cfg=ExecutionConfig(n_microbatches=2,
                                                   prefetch_depth=pf))
        state, m = eng.train_step(eng.init(jax.random.PRNGKey(0)), batch)
        states[pf] = (state, float(m["loss"]))
    assert states[0][1] == states[1][1]
    _assert_trees_bitwise(states[0][0].params, states[1][0].params,
                          f"{name} params")
    _assert_trees_bitwise(states[0][0].opt_state, states[1][0].opt_state,
                          f"{name} opt state")


def test_prefetch_covers_multi_group_and_mem_archs(make_engine):
    """Transition/mem handling (whisper enc-dec) and MoE/MLA layers go
    through the same restructured scans."""
    for arch in ("whisper-base", "deepseek-v2-lite-16b"):
        cfg = _cfg(arch)
        batch = make_batch(cfg, 4, 16)
        params = LayeredModel(cfg).init_params(jax.random.PRNGKey(0))
        outs = {}
        for pf in (0, 1):
            eng = make_engine("l2l-p", arch, exec_cfg=ExecutionConfig(
                n_microbatches=2, prefetch_depth=pf))
            outs[pf] = eng.grads(params, batch)
        _assert_trees_bitwise(outs[0][1], outs[1][1], arch)


def test_prefetch_prefill_and_decode_bit_identical(make_engine):
    cfg = _cfg("granite-3-8b")
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    outs = {}
    for pf in (0, 1):
        eng = make_engine("l2l", "granite-3-8b", exec_cfg=ExecutionConfig(
            n_microbatches=2, prefetch_depth=pf))
        params = eng.model.init_params(jax.random.PRNGKey(0))
        logits = eng.prefill(params, {"tokens": make_batch(cfg, 4, 16)[
            "tokens"]})
        caches, last = eng.decode_init(params, toks, live_seq=16)
        step_logits, _ = eng.decode_step(
            params, caches, jnp.argmax(last, -1)[:, None].astype(jnp.int32),
            jnp.int32(8))
        outs[pf] = (logits, last, step_logits)
    for a, b in zip(outs[0], outs[1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# memory model: the 2-slot footprint
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["l2l", "l2l_p"])
def test_memory_estimate_two_slot_footprint(mode):
    model = LayeredModel(get_config("bert-large"))
    r0 = estimate(model, batch=32, seq=512, n_microbatches=8, mode=mode,
                  offload_stash=True)
    r1 = estimate(model, batch=32, seq=512, n_microbatches=8, mode=mode,
                  offload_stash=True, prefetch_depth=1)
    # double buffering exactly doubles the device weight-transit slots...
    assert r1.params_device == 2 * r0.params_device
    # ...leaves EPS residency alone, and stays O(1) in depth
    assert r1.total_host == r0.total_host
    assert r1.total_device - r0.total_device == r0.params_device
    deep = LayeredModel(get_config("bert-large").replace(n_layers=96))
    rd = estimate(deep, batch=32, seq=512, n_microbatches=8, mode=mode,
                  offload_stash=True, prefetch_depth=1)
    assert rd.total_device == r1.total_device


def test_engine_memory_estimate_threads_prefetch(make_engine):
    e0 = make_engine("l2l-p", exec_cfg=ExecutionConfig(n_microbatches=2))
    e1 = make_engine("l2l-p", exec_cfg=ExecutionConfig(n_microbatches=2,
                                                       prefetch_depth=1))
    r0 = e0.memory_estimate(batch=8, seq=64)
    r1 = e1.memory_estimate(batch=8, seq=64)
    assert r1.params_device == 2 * r0.params_device
    # baseline mode has no relay; the knob must not perturb eq. (1)
    b0 = make_engine("baseline").memory_estimate(batch=8, seq=64)
    b1 = make_engine("baseline", exec_cfg=ExecutionConfig(
        n_microbatches=2, prefetch_depth=1)).memory_estimate(batch=8, seq=64)
    assert b0.params_device == b1.params_device


def test_registry_exec_overrides():
    eng = engines.create("l2l-p", get_config("bert-large", "smoke"),
                         ExecutionConfig(n_microbatches=4),
                         exec_overrides={"prefetch_depth": 1})
    assert eng.exec_cfg.prefetch_depth == 1
    assert eng.exec_cfg.n_microbatches == 4
    eng2 = engines.create("l2l", get_config("bert-large", "smoke"),
                          exec_overrides={"prefetch_depth": 1})
    assert eng2.exec_cfg.prefetch_depth == 1


def test_prefetch_depth_validated():
    # k >= 2 is a legal ring depth since the unified relay executor;
    # only negative depths are rejected
    assert ExecutionConfig(prefetch_depth=2).prefetch_depth == 2
    with pytest.raises(AssertionError):
        ExecutionConfig(prefetch_depth=-1)
