"""Compile-shape regression tests for the scan-over-segments driver.

The K > 1 stash schedule historically unrolled one relay per segment per
phase, so the lowered train step held ~3*ceil(N/K) scan instances and
trace/compile time grew linearly with depth.  ``segment_scan`` drives all
of a phase's segments through ONE outer lax.scan; these tests pin the
resulting invariant — the lowered program's while/scan instance count
does not depend on depth — and the dynamic-depth identity built on it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro import engine as engines
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig
from repro.optim import adam


def _cfg(n_layers):
    return get_config("bert-large", "smoke").replace(dtype="float32",
                                                     n_layers=n_layers)


def _while_count(eng, cfg):
    """Count while/scan instances in the lowered (uncompiled) step."""
    state = eng.abstract_state()
    batch = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), a.dtype),
        make_batch(cfg, 4, 8))
    hlo = jax.jit(eng.step_fn).lower(state, batch).as_text()
    return hlo.count("stablehlo.while")


@pytest.mark.parametrize("name", ["l2l", "l2l-p"])
def test_while_count_is_depth_invariant(name):
    """Depth 8 and depth 64 lower to the SAME number of scan instances
    (K > 1, G > 1, prefetch on): the program is O(1) in depth.  The
    constant depends only on N mod K — the short remainder runs as a
    static program outside the outer scan — never on N itself."""
    ec = ExecutionConfig(n_microbatches=2, stash_every=2,
                         layers_per_relay=2, prefetch_depth=1)
    counts = {}
    for n in (8, 64):
        cfg = _cfg(n)
        eng = engines.create(name, cfg, ec, optimizer=adam(), donate=False)
        counts[n] = _while_count(eng, cfg)
    assert counts[8] == counts[64], counts


def test_while_count_same_remainder_and_bounded():
    """K = 3 leaves remainder segments: equal N mod K -> equal count
    (8 vs 11), and a different remainder never lowers MORE instances at
    the deeper depth (8 vs 64) — no depth-proportional growth."""
    ec = ExecutionConfig(n_microbatches=2, stash_every=3,
                         layers_per_relay=2, prefetch_depth=1)
    counts = {}
    for n in (8, 11, 64):
        cfg = _cfg(n)
        eng = engines.create("l2l-p", cfg, ec, optimizer=adam(),
                             donate=False)
        counts[n] = _while_count(eng, cfg)
    assert counts[8] == counts[11], counts      # same remainder (2)
    assert counts[64] <= counts[8], counts      # remainder 1: no growth


def test_unrolled_program_grows_with_depth():
    """The historical unrolled driver (segment_scan=False) emits more
    scan instances at the deeper depth — the depth-proportional blowup
    the segment scan removes (kept compilable as the A/B baseline)."""
    ec = ExecutionConfig(n_microbatches=2, stash_every=3,
                         layers_per_relay=2, segment_scan=False)
    counts = {}
    for n in (6, 12):
        cfg = _cfg(n)
        eng = engines.create("l2l-p", cfg, ec, optimizer=adam(),
                             donate=False)
        counts[n] = _while_count(eng, cfg)
    assert counts[12] > counts[6], counts


def test_dynamic_depth_grads_bitwise_vs_static():
    """grads(params, batch, n) under dynamic_depth == the static depth-n
    program's grads BITWISE on the active rows, zeros on the tail rows."""
    CAP, n, K = 4, 3, 2
    cfg_cap = _cfg(CAP)
    batch = make_batch(cfg_cap, 4, 8)
    dyn = ExecutionConfig(n_microbatches=2, stash_every=K,
                          layers_per_relay=2, prefetch_depth=1,
                          dynamic_depth=True)
    e_dyn = engines.create("l2l-p", cfg_cap, dyn, optimizer=adam(),
                           donate=False)
    params = e_dyn.model.init_params(jax.random.PRNGKey(0))
    loss_d, g_d = e_dyn.grads(params, batch, n)

    stat = ExecutionConfig(n_microbatches=2, stash_every=K,
                           layers_per_relay=2, prefetch_depth=1)
    e_st = engines.create("l2l-p", _cfg(n), stat, optimizer=adam(),
                          donate=False)
    params_n = {"embed": params["embed"], "head": params["head"],
                "groups": tuple(jax.tree.map(lambda a: a[:n], g)
                                for g in params["groups"])}
    loss_s, g_s = e_st.grads(params_n, batch)

    assert float(loss_d) == float(loss_s)
    act = {"embed": g_d["embed"], "head": g_d["head"],
           "groups": tuple(jax.tree.map(lambda a: a[:n], g)
                           for g in g_d["groups"])}
    for a, b in zip(jax.tree.leaves(act), jax.tree.leaves(g_s)):
        assert bool(jnp.all(a == b))
    for t in jax.tree.leaves(tuple(jax.tree.map(lambda a: a[n:], g)
                                   for g in g_d["groups"])):
        assert bool(jnp.all(t == 0))


def test_dynamic_depth_one_compile_many_depths():
    """ONE jitted program serves every runtime depth: growing n_layers
    across calls adds no cache entries (the zero-recompile NAS loop)."""
    CAP = 4
    cfg = _cfg(CAP)
    batch = make_batch(cfg, 4, 8)
    ec = ExecutionConfig(n_microbatches=2, stash_every=2,
                         dynamic_depth=True)
    eng = engines.create("l2l-p", cfg, ec, optimizer=adam(), donate=False)
    params = eng.model.init_params(jax.random.PRNGKey(0))
    losses = [float(eng.grads(params, batch, n)[0]) for n in (2, 3, 4)]
    assert len(set(losses)) == 3          # depths really differ
    assert eng._fns["grads"]._cache_size() == 1
