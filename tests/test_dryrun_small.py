"""Multi-device lowering tests (subprocess: device count must be forced
before jax initializes, so these run out-of-process)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
sys.path.insert(0, {src!r})
import jax, numpy as np
from jax.sharding import Mesh
from repro.configs.base import InputShape
from repro.configs import base as cb
from repro.launch.build import build
cb.INPUT_SHAPES["t_train"] = InputShape("t_train", 64, 8, "train")
cb.INPUT_SHAPES["t_prefill"] = InputShape("t_prefill", 128, 4, "prefill")
cb.INPUT_SHAPES["t_decode"] = InputShape("t_decode", 256, 8, "decode")
mesh = Mesh(np.asarray(jax.devices()[:16]).reshape(4, 4), ("data", "model"))
results = {{}}
for arch in {archs!r}:
    for shape in ["t_train", "t_prefill", "t_decode"]:
        bs = build(arch, shape, mesh, variant="smoke")
        with mesh:
            co = jax.jit(bs.fn, in_shardings=bs.in_shardings,
                         out_shardings=bs.out_shardings).lower(*bs.args).compile()
        ma = co.memory_analysis()
        results[f"{{arch}}/{{shape}}"] = int(ma.temp_size_in_bytes)
print("RESULTS:" + json.dumps(results))
"""


def _run(archs):
    code = SCRIPT.format(src=os.path.join(REPO, "src"), archs=archs)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULTS:")]
    return json.loads(line[0][len("RESULTS:"):])


@pytest.mark.slow
def test_mesh_lowering_dense_and_moe():
    res = _run(["granite-3-8b", "grok-1-314b"])
    assert len(res) == 6
    assert all(v > 0 for v in res.values())


@pytest.mark.slow
def test_mesh_lowering_ssm_hybrid_audio():
    res = _run(["rwkv6-1.6b", "hymba-1.5b", "whisper-base"])
    assert len(res) == 9


@pytest.mark.slow
def test_mesh_lowering_mla_vlm():
    res = _run(["deepseek-v2-lite-16b", "internvl2-1b"])
    assert len(res) == 6


MOE_NUMERIC = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, {src!r})
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro import engine as engines
from repro.configs.base import get_config
from repro.models.model import LayeredModel
from repro.core.schedule import ExecutionConfig
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
cfg0 = get_config("deepseek-v2-lite-16b", "smoke").replace(
    dtype="float32", capacity_factor=100.0)
cfg1 = cfg0.replace(moe_ep_constraint=True)
B, S = 4, 32
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg0.vocab_size)
batch = {{"tokens": toks, "targets": toks,
          "mask": jnp.ones((B, S), jnp.float32)}}
params = LayeredModel(cfg0).init_params(jax.random.PRNGKey(0))
outs = {{}}
for name, cfg in [("global", cfg0), ("grouped", cfg1)]:
    fn = engines.create("baseline", LayeredModel(cfg),
                        ExecutionConfig(n_microbatches=1)).grads_fn
    with mesh:
        loss, grads = jax.jit(fn, in_shardings=(
            None, NamedSharding(mesh, P("data"))))(params, batch)
    outs[name] = (float(loss), grads)
l0, g0 = outs["global"]
l1, g1 = outs["grouped"]
err = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(a - b))), g0, g1)))
assert abs(l0 - l1) < 1e-4 and err < 1e-3, (l0, l1, err)
print("RESULTS:" + "{{}}")
"""


@pytest.mark.slow
def test_grouped_moe_dispatch_numerics_on_mesh():
    """The §Perf grouped (local-per-data-shard) MoE dispatch computes the
    SAME gradients as the global dispatch when capacity is ample —
    executed for real on an 8-device SPMD mesh."""
    code = MOE_NUMERIC.format(src=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
