"""Data pipeline determinism/sharding + checkpoint roundtrip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt
from repro.data.synthetic import DataConfig, SyntheticLM, add_modality_stubs


def test_data_deterministic():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=4, seed=7)
    a = SyntheticLM(cfg).batch(3)
    b = SyntheticLM(cfg).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 64)
    assert a["tokens"].dtype == np.int32


def test_data_next_token_targets():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=2, seed=1)
    b = SyntheticLM(cfg).batch(0)
    # targets are tokens shifted by one (same underlying stream)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])
    # mask zero exactly on pad targets
    np.testing.assert_array_equal(b["mask"] == 0.0, b["targets"] == 0)


def test_data_host_sharding_partitions_global_batch():
    g = DataConfig(vocab_size=500, seq_len=32, global_batch=8, seed=3)
    full = SyntheticLM(g).batch(5)
    parts = []
    for h in range(4):
        c = DataConfig(vocab_size=500, seq_len=32, global_batch=8, seed=3,
                       host_index=h, host_count=4)
        parts.append(SyntheticLM(c).batch(5)["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts, 0), full["tokens"])


def test_data_is_learnable_structure():
    """Motif repetition => strongly non-uniform bigram stats."""
    cfg = DataConfig(vocab_size=256, seq_len=512, global_batch=4, seed=0,
                     n_motifs=8)
    b = SyntheticLM(cfg).batch(0)
    toks = np.asarray(b["tokens"]).reshape(-1)
    pairs = toks[:-1].astype(np.int64) * 256 + toks[1:]
    top = np.bincount(pairs).max()
    assert top > 10  # repeated motifs make some bigrams frequent


def test_modality_stubs():
    from repro.configs.base import get_config
    cfg = get_config("whisper-base", "smoke")
    b = add_modality_stubs({"tokens": np.zeros((2, 8), np.int32)}, cfg)
    assert b["frames"].shape == (2, cfg.n_frames, cfg.d_model)
    cfg = get_config("internvl2-1b", "smoke")
    b = add_modality_stubs({"tokens": np.zeros((2, 8), np.int32)}, cfg)
    assert b["patches"].shape == (2, cfg.n_patches, cfg.vit_dim)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": (jnp.ones(4, jnp.bfloat16), {"c": jnp.int32(7)})}
    path = str(tmp_path / "ck")
    ckpt.save(path, tree, step=5)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)
    back = ckpt.restore(path, like)
    assert jax.tree.all(jax.tree.map(
        lambda x, y: bool(jnp.all(x == y)), tree, back))


def test_checkpoint_train_state_roundtrip(tmp_path):
    from repro import engine as engines
    from repro.configs.base import get_config
    cfg = get_config("bert-large", "smoke")
    eng = engines.create("l2l-p", cfg, donate=False)
    state = eng.init(jax.random.PRNGKey(0))
    d = str(tmp_path)
    eng.save(d, state, step=42)
    assert ckpt.latest_step(d) == 42
    restored, step = eng.restore(d)
    assert step == 42
    assert jax.tree.all(jax.tree.map(
        lambda x, y: bool(jnp.all(jnp.asarray(x) == jnp.asarray(y))),
        state.params, restored.params))
    assert int(restored.step) == int(state.step)


def test_checkpoint_structure_mismatch_rejected(tmp_path):
    path = str(tmp_path / "ck")
    ckpt.save(path, {"a": jnp.ones(3)})
    with pytest.raises(AssertionError):
        ckpt.restore(path, {"a": jnp.ones(3), "b": jnp.ones(2)})


def test_verify_memoizes_heavy_pass_until_files_change(tmp_path,
                                                       monkeypatch):
    """Repeated verify() of an unchanged snapshot runs the byte pass
    ONCE (then costs two stat calls); touching arrays.npz — the file the
    manifest does NOT protect against in-place rot — invalidates the
    memo, as does rewriting the snapshot.  A cached good verdict never
    shadows a fingerprint mismatch (that check is per-caller, uncached)."""
    import os
    path = str(tmp_path / "ck")
    ckpt.save(path, {"a": jnp.arange(8, dtype=jnp.float32)}, step=1,
              fingerprint="arch:L2:v1")

    calls = {"n": 0}
    real = ckpt._verify_bytes

    def counting(p, manifest):
        calls["n"] += 1
        return real(p, manifest)

    monkeypatch.setattr(ckpt, "_verify_bytes", counting)
    assert ckpt.verify(path) and ckpt.verify(path) and ckpt.verify(path)
    assert calls["n"] == 1

    # cheap structural checks stay live on the cached verdict
    assert not ckpt.verify(path, fingerprint="other:L9:v1")
    assert calls["n"] == 1

    # in-place damage to arrays.npz moves its mtime_ns -> fresh pass
    arrays = os.path.join(path, ckpt.ARRAYS)
    os.utime(arrays, ns=(0, os.stat(arrays).st_mtime_ns + 1))
    assert ckpt.verify(path)
    assert calls["n"] == 2

    # a rewrite (new bytes, new manifest) re-verifies too
    ckpt.save(path, {"a": jnp.zeros(8, jnp.float32)}, step=1)
    assert ckpt.verify(path)
    assert calls["n"] == 3
