"""Analytic memory/time model invariants (eqs. 1-7)."""
import itertools

import pytest

from repro.configs.base import get_config
from repro.core.memory_model import (estimate, estimate_serve, for_config,
                                     paper_worked_example)
from repro.core.schedule import ExecutionConfig
from repro.models.model import LayeredModel


def test_l2l_device_bytes_depth_independent():
    """Eq. (4): the device footprint must not grow with N."""
    devs = []
    for n in (12, 24, 96):
        model = LayeredModel(get_config("bert-large").replace(n_layers=n))
        r = estimate(model, batch=32, seq=512, n_microbatches=8,
                     mode="l2l_p", offload_stash=True)
        devs.append(r.total_device)
    assert devs[0] == devs[1] == devs[2]


def test_baseline_device_bytes_linear_in_depth():
    rs = []
    for n in (12, 24):
        model = LayeredModel(get_config("bert-large").replace(n_layers=n))
        r = estimate(model, batch=32, seq=512, mode="baseline")
        rs.append(r.total_device + r.opt_state)
    assert 1.8 < rs[1] / rs[0] < 2.2


def test_l2l_host_holds_model_and_opt():
    model = LayeredModel(get_config("bert-large"))
    r = estimate(model, batch=32, seq=512, mode="l2l_p",
                 offload_stash=True)
    b = estimate(model, batch=32, seq=512, mode="baseline")
    # host >= params + opt (what baseline kept on device)
    assert r.total_host >= b.params_device + b.opt_state


def test_stash_scales_with_batch_not_ub():
    model = LayeredModel(get_config("bert-large"))
    r8 = estimate(model, batch=8, seq=512, n_microbatches=2, mode="l2l")
    r32 = estimate(model, batch=32, seq=512, n_microbatches=8, mode="l2l")
    assert r32.stash == 4 * r8.stash
    a = estimate(model, batch=32, seq=512, n_microbatches=2, mode="l2l")
    b = estimate(model, batch=32, seq=512, n_microbatches=16, mode="l2l")
    assert a.stash == b.stash            # Table 5: ub count doesn't matter


@pytest.mark.parametrize("mode", ["l2l", "l2l_p"])
def test_group_prefetch_pack_grid(mode):
    """Device weight-transit footprint is G*(1+k) x the base eq. (2)/(3)
    term across the whole (layers_per_relay, prefetch_depth, pack_params)
    grid; EPS residency and byte totals are knob-independent; the DMA
    issue counts report per-stop copies x ceil(N/G) stops."""
    model = LayeredModel(get_config("bert-large"))   # 24 layers, 1 group
    base = estimate(model, batch=32, seq=512, n_microbatches=8, mode=mode,
                    offload_stash=True)
    n_leaves = base.relay_copies_weights
    assert n_leaves > 1                      # per-leaf relay, many copies
    for G, k, pk in itertools.product((1, 2, 3, 5), (0, 1, 2),
                                      (False, True)):
        r = estimate(model, batch=32, seq=512, n_microbatches=8, mode=mode,
                     offload_stash=True, prefetch_depth=k,
                     layers_per_relay=G, pack_params=pk)
        tag = f"G={G} k={k} pack={pk}"
        # the G*(1+k) device-footprint term (paper "layer(s)", plural)
        assert r.params_device == G * (1 + k) * base.params_device, tag
        # EPS residency and non-transit terms don't move
        assert r.total_host == base.total_host, tag
        assert r.stash == base.stash and r.activations == base.activations
        # trip count: ceil(24 / G) stops per pass
        assert r.relay_stops == -(-24 // G), tag
        # per-stop copies: layout-dependent, group-independent
        assert r.relay_copies_weights == (1 if pk else n_leaves), tag
        if mode == "l2l_p":
            assert r.relay_copies_opt == (2 if pk else 2 * n_leaves), tag
        else:
            assert r.relay_copies_opt == 0, tag


def test_group_footprint_caps_at_group_depth():
    """G beyond the deepest group adds no residency: the slot is at most
    the group's whole stack (the remainder-only pass of relay_scan)."""
    model = LayeredModel(get_config("bert-large").replace(n_layers=5))
    r5 = estimate(model, batch=8, seq=128, mode="l2l_p",
                  layers_per_relay=5)
    r9 = estimate(model, batch=8, seq=128, mode="l2l_p",
                  layers_per_relay=9)
    assert r9.params_device == r5.params_device
    assert r5.relay_stops == r9.relay_stops == 1


def test_group_stops_sum_over_groups_and_remainder():
    """Multi-group arch (whisper enc+dec): stops are the SUM of per-group
    ceilings, so a depth not divisible by G pays its remainder stop."""
    model = LayeredModel(get_config("whisper-base"))
    depths = [g.n_layers for g in model.groups]
    for G in (1, 2, 3, 5):
        r = estimate(model, batch=8, seq=128, mode="l2l_p",
                     layers_per_relay=G)
        assert r.relay_stops == sum(-(-d // G) for d in depths)


@pytest.mark.parametrize("mode", ["l2l", "l2l_p"])
def test_stash_every_grid(mode):
    """Constant-memory stash term: ceil(N/K) boundaries per group, every
    other term untouched, and the recompute price reported (N - ceil(N/K)
    extra layer-forwards over ceil((len-1)/G) extra stops per segment).
    K=1 must reproduce today's model byte-for-byte."""
    model = LayeredModel(get_config("bert-large"))   # 24 layers, 1 group
    base = estimate(model, batch=32, seq=512, n_microbatches=8, mode=mode,
                    offload_stash=True)
    assert base.stash_boundaries == 24
    assert base.recompute_layers == 0 and base.recompute_stops == 0
    k1 = estimate(model, batch=32, seq=512, n_microbatches=8, mode=mode,
                  offload_stash=True, stash_every=1)
    assert k1 == base                                # K=1 byte-identical
    per_boundary = base.stash // 24
    for K, G in itertools.product((1, 2, 3, 5, 7, 24, 30), (1, 2, 3)):
        r = estimate(model, batch=32, seq=512, n_microbatches=8, mode=mode,
                     offload_stash=True, stash_every=K, layers_per_relay=G)
        tag = f"K={K} G={G}"
        n_ckpt = -(-24 // K)
        assert r.stash_boundaries == n_ckpt, tag
        assert r.stash == n_ckpt * per_boundary, tag     # ceil(N/K)*mb*A
        assert r.recompute_layers == 24 - n_ckpt, tag
        # K=1 relays G-layer slots; K>1 runs every relay over one
        # K-segment, so the slot is capped at min(G, K) layers — K < G
        # shrinks the transit footprint too
        slot_layers = G if K == 1 else min(G, K)
        assert r.params_device == slot_layers * base.params_device, tag
        # recompute working set in the stash tier: largest segment - 1
        assert r.recompute_buffer == \
            (min(K, 24) - 1 if K > 1 else 0) * per_boundary, tag
        assert r.activations == base.activations, tag
        # K=1: one relay over the depth; K>1 segments every pass into
        # one relay per segment (ceil(len/G) stops each)
        segs = [(s, min(s + K, 24)) for s in range(0, 24, K)]
        exp_stops = (-(-24 // G) if K == 1
                     else sum(-(-(s1 - s0) // G) for s0, s1 in segs))
        assert r.relay_stops == exp_stops, tag
        # recompute stops: each segment re-streams its first len-1 layers
        assert r.recompute_stops == sum(
            -(-(s1 - s0 - 1) // G) for s0, s1 in segs if s1 - s0 > 1), tag


def test_stash_every_offload_composition():
    """The stash tier — the ceil(N/K) checkpoints AND the transient
    recompute buffer — moves wholesale between tiers: device bytes with
    offload off, host bytes with offload on; the other tier doesn't see
    either term."""
    model = LayeredModel(get_config("bert-large"))
    for K in (1, 3, 8):
        on = estimate(model, batch=32, seq=512, n_microbatches=8,
                      mode="l2l_p", offload_stash=True, stash_every=K)
        off = estimate(model, batch=32, seq=512, n_microbatches=8,
                       mode="l2l_p", offload_stash=False, stash_every=K)
        assert on.stash == off.stash                  # same bytes, moved
        assert on.recompute_buffer == off.recompute_buffer
        tier = on.stash + on.recompute_buffer
        assert on.total_device + tier == off.total_device
        assert off.total_host + tier == on.total_host


def test_stash_every_monotone_and_constant_memory_point():
    """With the stash offloaded (eq. 4) total_device is monotone
    non-increasing in K — the boundaries round-trip through the host one
    at a time, so the DEVICE never sees K.  On device (offload off) the
    stash tier pays the classic Chen curve ceil(N/K) + K - 1 boundaries:
    sublinear at intermediate K, back to N at the extremes.  And the
    offloaded HOST stash stops growing linearly: at K >= N it is one
    boundary per group regardless of depth — the true constant-device +
    sublinear-host memory point."""
    model = LayeredModel(get_config("bert-large"))
    base = estimate(model, batch=32, seq=512, n_microbatches=8,
                    mode="l2l_p", offload_stash=True)
    per_boundary = base.stash // 24
    prev = None
    for K in (1, 2, 3, 4, 6, 8, 12, 24, 48):
        on = estimate(model, batch=32, seq=512, n_microbatches=8,
                      mode="l2l_p", offload_stash=True, stash_every=K)
        if prev is not None:
            assert on.total_device <= prev, f"K={K}"
        prev = on.total_device
        off = estimate(model, batch=32, seq=512, n_microbatches=8,
                       mode="l2l_p", offload_stash=False, stash_every=K)
        boundaries = -(-24 // K) + (min(K, 24) - 1 if K > 1 else 0)
        assert off.stash + off.recompute_buffer == \
            boundaries * per_boundary, f"K={K}"
    # the sqrt-N sweet spot beats both extremes on device
    dev = {K: estimate(model, batch=32, seq=512, n_microbatches=8,
                       mode="l2l_p", offload_stash=False,
                       stash_every=K).total_device for K in (1, 5, 24)}
    assert dev[5] < dev[1] and dev[5] < dev[24]
    # depth-independence of the stash at K >= N (one checkpoint/group)
    stashes = []
    for n in (12, 24, 96):
        m = LayeredModel(get_config("bert-large").replace(n_layers=n))
        r = estimate(m, batch=32, seq=512, n_microbatches=8, mode="l2l_p",
                     offload_stash=True, stash_every=96)
        stashes.append(r.stash)
        assert r.stash_boundaries == 1
    assert stashes[0] == stashes[1] == stashes[2]


def test_stash_every_multi_group_sums_ceilings():
    """Whisper (enc 6 + dec 6... group depths differ per config): the
    boundary count is the SUM of per-group ceilings."""
    model = LayeredModel(get_config("whisper-base"))
    depths = [g.n_layers for g in model.groups]
    for K in (1, 2, 3, 5, 100):
        r = estimate(model, batch=8, seq=128, mode="l2l_p", stash_every=K)
        assert r.stash_boundaries == sum(-(-d // K) for d in depths)
        assert r.recompute_layers == sum(d - -(-d // K) for d in depths)


def test_engine_memory_estimate_threads_stash_every(make_engine):
    e0 = make_engine("l2l-p", exec_cfg=ExecutionConfig(n_microbatches=2))
    e1 = make_engine("l2l-p", exec_cfg=ExecutionConfig(
        n_microbatches=2, stash_every=2))
    r0 = e0.memory_estimate(batch=8, seq=64)
    r1 = e1.memory_estimate(batch=8, seq=64)
    n_layers = sum(g.n_layers for g in e0.model.groups)
    assert r0.stash_boundaries == n_layers
    assert r1.stash_boundaries == -(-n_layers // 2)
    assert r1.stash == r0.stash // n_layers * -(-n_layers // 2)


def test_baseline_mode_ignores_relay_knobs():
    model = LayeredModel(get_config("bert-large"))
    b0 = estimate(model, batch=32, seq=512, mode="baseline")
    b1 = estimate(model, batch=32, seq=512, mode="baseline",
                  prefetch_depth=2, layers_per_relay=4, pack_params=True,
                  stash_every=4)
    assert b0.params_device == b1.params_device
    assert b1.relay_stops == 0


def test_engine_memory_estimate_threads_group(make_engine):
    """Engine.memory_estimate must pass its exec config's G and k."""
    e0 = make_engine("l2l-p", exec_cfg=ExecutionConfig(n_microbatches=2))
    e1 = make_engine("l2l-p", exec_cfg=ExecutionConfig(
        n_microbatches=2, layers_per_relay=2, prefetch_depth=2))
    r0 = e0.memory_estimate(batch=8, seq=64)
    r1 = e1.memory_estimate(batch=8, seq=64)
    # smoke bert has 2 layers: G=2 slots, k=2 ring -> 2*(1+2) footprints
    assert r1.params_device == 2 * (1 + 2) * r0.params_device
    n_layers = sum(g.n_layers for g in e0.model.groups)
    assert r0.relay_stops == n_layers
    assert r1.relay_stops == -(-n_layers // 2)


def test_serve_pool_bytes_scale_with_pages_not_slots():
    """The point of paging: KV bytes follow the PHYSICAL pool
    (n_pages * page_size), not max_batch * max_seq — doubling the slot
    count moves only the per-slot recurrent state."""
    model = LayeredModel(get_config("granite-3-8b", "smoke"))
    base = estimate_serve(model, max_batch=4, page_size=8, n_pages=16,
                          max_seq=64)
    wide = estimate_serve(model, max_batch=8, page_size=8, n_pages=16,
                          max_seq=64)
    assert wide.kv_page_bytes == base.kv_page_bytes
    more = estimate_serve(model, max_batch=4, page_size=8, n_pages=32,
                          max_seq=64)
    assert more.kv_page_bytes == 2 * base.kv_page_bytes
    # granite is attention-only: no per-slot recurrent state
    assert base.slot_state_bytes == 0
    # the pool shows up in the device total
    assert base.total_device >= base.kv_page_bytes


def test_serve_slot_state_follows_max_batch_for_recurrent():
    model = LayeredModel(get_config("rwkv6-1.6b", "smoke"))
    b4 = estimate_serve(model, max_batch=4, page_size=8, n_pages=16,
                        max_seq=64)
    b8 = estimate_serve(model, max_batch=8, page_size=8, n_pages=16,
                        max_seq=64)
    assert b4.slot_state_bytes > 0
    assert b8.slot_state_bytes == 2 * b4.slot_state_bytes
    # rwkv has NO paged leaves: the whole cache is per-slot state
    assert b4.kv_page_bytes == 0


def test_serve_relay_terms_grid():
    """Per-tick relay DMA: sum of ceil(n_layers/G) over decode groups,
    independent of how many requests are in flight — the amortization
    continuous batching banks on.  weight_stream off keeps the whole
    stack device-resident and zeroes the per-tick relay count."""
    model = LayeredModel(get_config("granite-3-8b", "smoke"))
    n = sum(g.n_layers for g in model.decode_groups())
    per_layer = estimate_serve(
        model, max_batch=4, page_size=8, n_pages=16, max_seq=64,
        weight_stream=True).params_device
    for G, k in itertools.product((1, 2, 3), (0, 1, 2)):
        r = estimate_serve(model, max_batch=4, page_size=8, n_pages=16,
                           max_seq=64, weight_stream=True,
                           layers_per_relay=G, prefetch_depth=k)
        tag = f"G={G} k={k}"
        assert r.relay_stops_per_tick == -(-n // G), tag
        # same pool bytes regardless of relay knobs
        assert r.kv_page_bytes > 0, tag
        # streamed: EPS holds the whole stack, the device holds the
        # (1 + k)-slot ring of min(G, depth)-layer slots
        assert r.params_host == n * per_layer, tag
        assert r.params_device == (1 + k) * min(G, n) * per_layer, tag
    res = estimate_serve(model, max_batch=4, page_size=8, n_pages=16,
                         max_seq=64, weight_stream=False)
    assert res.relay_stops_per_tick == 0
    assert res.params_host == 0 and res.params_device > 0
    assert res.opt_state == 0                  # inference: no optimizer


def test_engine_serve_memory_estimate_threads_knobs(make_engine):
    from repro.serve.engine import ServeConfig
    scfg = ServeConfig(max_batch=4, page_size=8, n_pages=16, max_seq=64)
    e0 = make_engine("l2l", arch="granite-3-8b",
                     exec_cfg=ExecutionConfig(weight_stream=True))
    e1 = make_engine("l2l", arch="granite-3-8b",
                     exec_cfg=ExecutionConfig(weight_stream=True,
                                              layers_per_relay=2,
                                              prefetch_depth=1))
    r0 = e0.serve_memory_estimate(scfg)
    r1 = e1.serve_memory_estimate(scfg)
    n = sum(g.n_layers for g in e0.model.decode_groups())
    assert r0.relay_stops_per_tick == n
    assert r1.relay_stops_per_tick == -(-n // 2)
    # G=2 slots, k=1 ring: 2*(1+1) single-layer footprints
    assert r1.params_device == 2 * (1 + 1) * r0.params_device
    assert r0.kv_page_bytes == r1.kv_page_bytes


def test_paper_worked_example_numbers():
    tm = paper_worked_example()
    assert abs(tm.l2l() - 2.92) < 0.15
    assert abs(tm.l2l_p() - 2.45) < 0.15
    assert tm.baseline() < tm.l2l_p() < tm.l2l()


def test_l2lp_hides_relay_when_compute_bound():
    tm = paper_worked_example()
    # with fast host link the L2L-p overhead over pure compute vanishes
    fast = tm.__class__(**{**tm.__dict__, "hb": 1e12, "o_tc": 0.0})
    assert abs(fast.l2l_p()
               - fast.n_layers * fast.u * (2 * fast.f_t + fast.b_t)) < 1e-9


def test_for_config_sane():
    model = LayeredModel(get_config("granite-3-8b"))
    tm = for_config(model, batch=16, seq=4096, u=4)
    assert tm.baseline() > 0
    assert tm.l2l() > tm.baseline()      # recompute overhead


# ===========================================================================
# Storage tier terms (tiers=3): host/disk split, ring cap, sharding
# ===========================================================================
def test_tiers2_has_no_disk_terms():
    model = LayeredModel(get_config("bert-large"))
    r = estimate(model, batch=32, seq=512, n_microbatches=8, mode="l2l_p",
                 offload_stash=True, tiers=2, host_budget=1 << 20)
    assert r.total_disk == 0 and r.params_disk == 0 and r.opt_disk == 0
    assert r.demoted_layers == 0 and r.disk_reads == 0


def test_tier_disk_terms_conserve_state_across_budget_grid():
    """Demotion is a pure host->disk MOVE: for every (G, k, budget)
    point host+disk per role is budget-invariant, the demoted count is
    exactly what the runtime's demote_plan returns (shared policy), the
    read count is ceil(d/G) stops x (1 + opt_slots) roles, and the DEVICE
    never sees the tier knob."""
    from repro.core.tierstore import demote_plan, ring_depth
    model = LayeredModel(get_config("bert-large"))   # 24 layers, 1 group
    base = estimate(model, batch=32, seq=512, n_microbatches=8,
                    mode="l2l_p", offload_stash=True)
    w_pl = base.params_host // 24
    state_pl = 3 * w_pl                  # demotable row: w + m + v (adam)
    budgets = [0, state_pl * 5, state_pl * 16, state_pl * 24 + 1]
    for G, k in itertools.product((1, 3), (0, 2)):
        two = estimate(model, batch=32, seq=512, n_microbatches=8,
                       mode="l2l_p", offload_stash=True,
                       layers_per_relay=G, prefetch_depth=k)
        prev_disk = None
        for budget in budgets:
            r = estimate(model, batch=32, seq=512, n_microbatches=8,
                         mode="l2l_p", offload_stash=True, tiers=3,
                         layers_per_relay=G, prefetch_depth=k,
                         host_budget=budget)
            tag = f"G={G} k={k} budget={budget}"
            hot = demote_plan([state_pl], [24], budget)
            dem = 24 - hot[0]
            assert r.demoted_layers == dem, tag
            # conservation: nothing created or lost by the move
            assert r.params_host + r.params_disk == base.params_host, tag
            assert r.opt_state + r.opt_disk == base.opt_state, tag
            assert r.total_disk == r.params_disk + r.opt_disk, tag
            # demoted rows are read back ceil(d/G) stops x 3 roles (adam)
            assert r.disk_reads == (-(-dem // G)) * 3 if dem else True, tag
            # placement below the device: eq. (4) terms untouched
            assert r.total_device == two.total_device, tag
            assert r.stash == two.stash, tag
            if prev_disk is not None:       # bigger budget, less disk
                assert r.total_disk <= prev_disk, tag
            prev_disk = r.total_disk
            if dem:
                exp_cap = ring_depth(k, G * state_pl,
                                     max(0, budget - hot[0] * state_pl),
                                     bounded=budget > 0)
                assert r.disk_read_ahead_cap == exp_cap, tag


def test_tier_ring_cap_shrinks_with_budget_slack():
    """The read-ahead cap mirrors the runtime watchdog: unbounded budget
    keeps the configured depth; a tight budget shrinks it toward the
    1-in-flight floor instead of letting the ring blow the budget."""
    model = LayeredModel(get_config("bert-large"))
    free = estimate(model, batch=32, seq=512, n_microbatches=8,
                    mode="l2l_p", offload_stash=True, tiers=3,
                    prefetch_depth=4, host_budget=0)
    assert free.disk_read_ahead_cap == 4          # budget 0 = unbounded
    state_pl = (free.params_host + free.params_disk
                + free.opt_state + free.opt_disk) // 24
    tight = estimate(model, batch=32, seq=512, n_microbatches=8,
                     mode="l2l_p", offload_stash=True, tiers=3,
                     prefetch_depth=4, host_budget=state_pl + 1)
    assert tight.demoted_layers == 23
    assert tight.disk_read_ahead_cap == 1         # watchdog floor


def test_tier_model_shards_divide_state_not_activations():
    """model_shards divides every per-layer state byte term (ceil per
    leaf-shard) but NOT the per-replica activation/stash terms — the
    budget is then per host."""
    model = LayeredModel(get_config("bert-large"))
    kw = dict(batch=32, seq=512, n_microbatches=8, mode="l2l_p",
              offload_stash=True, tiers=3, host_budget=0)
    r1 = estimate(model, **kw, model_shards=1)
    r4 = estimate(model, **kw, model_shards=4)
    for f in ("params_device", "params_disk", "opt_disk"):
        v1, v4 = getattr(r1, f), getattr(r4, f)
        assert v1 // 4 <= v4 <= v1 // 4 + 24 * 4, f   # ceil slack per row
    assert r4.activations == r1.activations
    assert r4.stash == r1.stash
    assert r4.total_disk < r1.total_disk


def test_tier_certification_is_feasible_for_100b_class():
    """The acceptance bar in one line: a >100B arch under a 16 GiB device
    budget with the overflow accounted on disk (the detailed per-arch
    certification — qwen-110b and sharded grok-314b — lives in
    tests/test_tierstore.py)."""
    GiB = 1 << 30
    model = LayeredModel(get_config("qwen1.5-110b"))
    r = estimate(model, batch=8, seq=2048, n_microbatches=8, mode="l2l_p",
                 offload_stash=True, param_dtype_bytes=2, stash_every=4,
                 pack_params=True, tiers=3, host_budget=512 * GiB)
    assert r.total_device <= 16 * GiB
    assert r.total_disk > 0 and r.demoted_layers > 0
