"""Analytic memory/time model invariants (eqs. 1-7)."""
import pytest

from repro.configs.base import get_config
from repro.core.memory_model import (estimate, for_config,
                                     paper_worked_example)
from repro.models.model import LayeredModel


def test_l2l_device_bytes_depth_independent():
    """Eq. (4): the device footprint must not grow with N."""
    devs = []
    for n in (12, 24, 96):
        model = LayeredModel(get_config("bert-large").replace(n_layers=n))
        r = estimate(model, batch=32, seq=512, n_microbatches=8,
                     mode="l2l_p", offload_stash=True)
        devs.append(r.total_device)
    assert devs[0] == devs[1] == devs[2]


def test_baseline_device_bytes_linear_in_depth():
    rs = []
    for n in (12, 24):
        model = LayeredModel(get_config("bert-large").replace(n_layers=n))
        r = estimate(model, batch=32, seq=512, mode="baseline")
        rs.append(r.total_device + r.opt_state)
    assert 1.8 < rs[1] / rs[0] < 2.2


def test_l2l_host_holds_model_and_opt():
    model = LayeredModel(get_config("bert-large"))
    r = estimate(model, batch=32, seq=512, mode="l2l_p",
                 offload_stash=True)
    b = estimate(model, batch=32, seq=512, mode="baseline")
    # host >= params + opt (what baseline kept on device)
    assert r.total_host >= b.params_device + b.opt_state


def test_stash_scales_with_batch_not_ub():
    model = LayeredModel(get_config("bert-large"))
    r8 = estimate(model, batch=8, seq=512, n_microbatches=2, mode="l2l")
    r32 = estimate(model, batch=32, seq=512, n_microbatches=8, mode="l2l")
    assert r32.stash == 4 * r8.stash
    a = estimate(model, batch=32, seq=512, n_microbatches=2, mode="l2l")
    b = estimate(model, batch=32, seq=512, n_microbatches=16, mode="l2l")
    assert a.stash == b.stash            # Table 5: ub count doesn't matter


def test_paper_worked_example_numbers():
    tm = paper_worked_example()
    assert abs(tm.l2l() - 2.92) < 0.15
    assert abs(tm.l2l_p() - 2.45) < 0.15
    assert tm.baseline() < tm.l2l_p() < tm.l2l()


def test_l2lp_hides_relay_when_compute_bound():
    tm = paper_worked_example()
    # with fast host link the L2L-p overhead over pure compute vanishes
    fast = tm.__class__(**{**tm.__dict__, "hb": 1e12, "o_tc": 0.0})
    assert abs(fast.l2l_p()
               - fast.n_layers * fast.u * (2 * fast.f_t + fast.b_t)) < 1e-9


def test_for_config_sane():
    model = LayeredModel(get_config("granite-3-8b"))
    tm = for_config(model, batch=16, seq=4096, u=4)
    assert tm.baseline() > 0
    assert tm.l2l() > tm.baseline()      # recompute overhead
