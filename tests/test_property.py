"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed: property tests skipped")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.models.attention import attend, expand_kv
from repro.models.common import apply_rope
from repro.models.moe import moe_spec
from repro.models.common import materialize
from repro.optim import adam, clip_by_norm, tree_global_norm

SET = dict(deadline=None, max_examples=20,
           suppress_health_check=[HealthCheck.too_slow])


# ---------------------------------------------------------------------------
# invariant: chunked online-softmax attention == unchunked
# ---------------------------------------------------------------------------
@settings(**SET)
@given(st.integers(1, 3), st.integers(1, 4),
       st.sampled_from([16, 48, 64, 96]),
       st.sampled_from([0, 8, 24]),
       st.booleans(), st.integers(0, 2 ** 31 - 1))
def test_chunked_attention_equals_full(B, H, S, window, causal, seed):
    D = 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    full = attend(q, k, v, pos, pos, causal=causal, window=window, chunk=0)
    chunked = attend(q, k, v, pos, pos, causal=causal, window=window,
                     chunk=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# invariant: RoPE is a rotation — it preserves vector norms exactly
# ---------------------------------------------------------------------------
@settings(**SET)
@given(st.integers(1, 4), st.sampled_from([1.0, 0.5]),
       st.integers(0, 2 ** 31 - 1))
def test_rope_preserves_norm(B, fraction, seed):
    S, H, D = 8, 2, 32
    x = jax.random.normal(jax.random.PRNGKey(seed), (B, S, H, D))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    y = apply_rope(x, pos, 10000.0, fraction)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        np.asarray(jnp.linalg.norm(y, axis=-1)), rtol=1e-5)


def test_rope_relative_property():
    """q.k after rope depends only on relative distance."""
    D = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, D))
    def score(pq, pk):
        qq = apply_rope(q, jnp.full((1, 1), pq, jnp.int32), 1e4)
        kk = apply_rope(k, jnp.full((1, 1), pk, jnp.int32), 1e4)
        return float(jnp.sum(qq * kk))
    assert abs(score(5, 3) - score(10, 8)) < 1e-4
    assert abs(score(7, 7) - score(0, 0)) < 1e-4


# ---------------------------------------------------------------------------
# invariant: GQA expand_kv replicates kv heads in query-group order
# ---------------------------------------------------------------------------
@settings(**SET)
@given(st.integers(1, 2), st.integers(1, 4), st.integers(1, 4),
       st.integers(0, 2 ** 31 - 1))
def test_expand_kv(B, KV, rep, seed):
    S, D = 4, 8
    k = jax.random.normal(jax.random.PRNGKey(seed), (B, S, KV, D))
    e = expand_kv(k, rep)
    assert e.shape == (B, S, KV * rep, D)
    for h in range(KV * rep):
        np.testing.assert_array_equal(np.asarray(e[:, :, h]),
                                      np.asarray(k[:, :, h // rep]))


# ---------------------------------------------------------------------------
# invariant: MoE dense path == capacity path when capacity is ample
# ---------------------------------------------------------------------------
class _MoECfg:
    d_model = 32
    d_ff_expert = 16
    n_experts = 4
    n_shared_experts = 0
    experts_per_token = 2
    capacity_factor = 100.0     # ample: no drops
    router_aux_coef = 0.01
    gated_mlp = True
    act = "silu"
    moe_ep_constraint = False


@settings(**SET)
@given(st.integers(0, 2 ** 31 - 1))
def test_moe_dense_equals_capacity(seed):
    cfg = _MoECfg()
    spec = moe_spec(cfg)
    w = materialize(spec, jax.random.PRNGKey(seed))
    # T = 6 <= 2E triggers dense; reshape to force capacity path with same
    # tokens via a larger batch of identical rows is awkward — instead call
    # the two internals directly.
    from repro.models.moe import _route, _moe_dense, _moe_capacity
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (40, cfg.d_model))
    tw, ti, aux = _route(w, x, cfg)
    yd = _moe_dense(w, x, tw, ti, cfg)
    yc = _moe_capacity(w, x, tw, ti, cfg)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yc), atol=1e-4)
    assert np.isfinite(float(aux))


@settings(**SET)
@given(st.integers(0, 2 ** 31 - 1))
def test_moe_router_weights_normalized(seed):
    cfg = _MoECfg()
    from repro.models.moe import _route
    w = materialize(moe_spec(cfg), jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (16, cfg.d_model))
    tw, ti, _ = _route(w, x, cfg)
    np.testing.assert_allclose(np.asarray(tw.sum(-1)), 1.0, atol=1e-5)
    # top-k ids are distinct per token
    assert all(len(set(row)) == len(row) for row in np.asarray(ti))


# ---------------------------------------------------------------------------
# invariant: clip_by_norm bounds the subtree norm; adam step bounded by lr
# ---------------------------------------------------------------------------
@settings(**SET)
@given(st.floats(1e-4, 10.0), st.integers(0, 2 ** 31 - 1))
def test_clip_by_norm(max_norm, seed):
    tree = {"a": jax.random.normal(jax.random.PRNGKey(seed), (7, 3)) * 10,
            "b": jax.random.normal(jax.random.PRNGKey(seed + 1), (5,)) * 10}
    clipped, pre = clip_by_norm(tree, max_norm)
    post = float(tree_global_norm(clipped))
    assert post <= max_norm * 1.001
    if float(pre) <= max_norm:
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(tree["a"]), rtol=1e-6)


@settings(**SET)
@given(st.integers(0, 2 ** 31 - 1))
def test_adam_update_bounded(seed):
    opt = adam(lr=1e-2)
    p = {"w": jax.random.normal(jax.random.PRNGKey(seed), (11,))}
    g = {"w": jax.random.normal(jax.random.PRNGKey(seed + 1), (11,)) * 100}
    s = opt.init(p)
    newp, _ = opt.update(g, s, p, jnp.int32(0))
    # |delta| <= lr * bias-correction bound (~ lr / (1-b1) early on)
    delta = float(jnp.max(jnp.abs(newp["w"] - p["w"])))
    assert delta <= 1e-2 * 12


# ---------------------------------------------------------------------------
# SYSTEM-LEVEL invariant: a full L2L engine step computes baseline grads
# for ANY (depth, stash_every, layers_per_relay, prefetch, pack,
# transport) point
# ---------------------------------------------------------------------------
# engines are rebuilt from scratch every example, so the function-scoped
# make_engine fixture carries no state between draws
_FIXTURE_HC = [hc for hc in [getattr(HealthCheck, "function_scoped_fixture",
                                     None)] if hc is not None]


@settings(deadline=None, max_examples=6,
          suppress_health_check=[HealthCheck.too_slow] + _FIXTURE_HC)
@given(depth=st.integers(2, 6), stash_every=st.integers(1, 8),
       group=st.integers(1, 4), prefetch=st.integers(0, 2),
       pack=st.booleans(), transport=st.sampled_from(["xla", "pallas"]),
       dynamic=st.booleans(), seed=st.integers(0, 2 ** 31 - 1))
def test_l2l_engine_matches_baseline_random_schedule(
        make_engine, depth, stash_every, group, prefetch, pack, transport,
        dynamic, seed):
    """The whole execution-schedule knob space is gradient-preserving:
    for random (depth, K, G, prefetch_depth, pack_params, transport)
    tuples — K and G free to exceed the depth, depths free to leave
    remainder segments and remainder relay stops, slots free to move via
    device_put or the Pallas DMA copy kernel — the l2l engine's grads on
    a random batch match the baseline reference engine's.  When
    ``dynamic`` is drawn, a dynamic_depth engine at capacity
    K*ceil(depth/K) additionally runs the SAME depth as a runtime operand
    and must match the static-depth program BITWISE on the active rows
    (zeros on the tail).  Today's kernel/optimizer invariants above never
    run a full engine step; this one does."""
    from conftest import make_batch
    from repro.configs.base import get_config
    from repro.core.schedule import ExecutionConfig
    cfg_full = get_config("bert-large", "smoke").replace(dtype="float32")
    cap = stash_every * -(-depth // stash_every)
    params_cap = make_engine(
        "l2l", cfg=cfg_full.replace(n_layers=cap),
        exec_cfg=ExecutionConfig()).model.init_params(
            jax.random.PRNGKey(seed))
    cfg = cfg_full.replace(n_layers=depth)
    params = {"embed": params_cap["embed"], "head": params_cap["head"],
              "groups": tuple(jax.tree.map(lambda a: a[:depth], g)
                              for g in params_cap["groups"])}
    e_base = make_engine("baseline", cfg=cfg,
                         exec_cfg=ExecutionConfig(n_microbatches=2))
    e_l2l = make_engine("l2l", cfg=cfg, exec_cfg=ExecutionConfig(
        n_microbatches=2, stash_every=stash_every, layers_per_relay=group,
        prefetch_depth=prefetch, pack_params=pack, transport=transport))
    batch = make_batch(cfg, 4, 8, seed=seed)
    loss_b, gb = e_base.grads(params, batch)
    loss_l, gl = e_l2l.grads(params, batch)
    assert abs(float(loss_b) - float(loss_l)) < 1e-4
    errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), gb, gl)
    assert max(jax.tree.leaves(errs)) < 1e-4
    if dynamic:
        e_dyn = make_engine(
            "l2l", cfg=cfg_full.replace(n_layers=cap),
            exec_cfg=ExecutionConfig(
                n_microbatches=2, stash_every=stash_every,
                layers_per_relay=group, prefetch_depth=prefetch,
                pack_params=pack, transport=transport,
                dynamic_depth=True))
        loss_d, gd = e_dyn.grads(params_cap, batch, depth)
        assert float(loss_d) == float(loss_l)
        act = {"embed": gd["embed"], "head": gd["head"],
               "groups": tuple(jax.tree.map(lambda a: a[:depth], g)
                               for g in gd["groups"])}
        for a, b in zip(jax.tree.leaves(act), jax.tree.leaves(gl)):
            assert bool(jnp.all(a == b))
        for t in jax.tree.leaves(tuple(jax.tree.map(lambda a: a[depth:], g)
                                       for g in gd["groups"])):
            assert bool(jnp.all(t == 0))


# ---------------------------------------------------------------------------
# invariant: L2L gradient identity holds for random microbatch splits
# ---------------------------------------------------------------------------
@settings(deadline=None, max_examples=6,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.sampled_from([1, 2, 4, 8]), st.integers(0, 2 ** 31 - 1))
def test_l2l_identity_random_ub(ub, seed):
    from conftest import make_batch
    from repro import engine as engines
    from repro.configs.base import get_config
    from repro.core.schedule import ExecutionConfig
    cfg = get_config("bert-large", "smoke").replace(dtype="float32")
    ec = ExecutionConfig(n_microbatches=ub)
    e_base = engines.create("baseline", cfg, ec, donate=False)
    e_l2l = engines.create("l2l", cfg, ec, donate=False)
    params = e_base.model.init_params(jax.random.PRNGKey(seed))
    batch = make_batch(cfg, 8, 8, seed=seed)
    _, gb = e_base.grads(params, batch)
    _, gl = e_l2l.grads(params, batch)
    errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), gb, gl)
    assert max(jax.tree.leaves(errs)) < 1e-4


# ---------------------------------------------------------------------------
# invariant: checkpoint save/restore is a byte-identical round trip for
# arbitrary pytrees and dtypes (incl. the bf16 raw-bits path), and every
# snapshot it writes passes its own integrity verification
# ---------------------------------------------------------------------------
_CKPT_DTYPES = ["float32", "float16", "bfloat16", "int32", "uint8"]


@st.composite
def _ckpt_leaf(draw):
    dt = draw(st.sampled_from(_CKPT_DTYPES))
    shape = tuple(draw(st.lists(st.integers(1, 4), min_size=0, max_size=3)))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.default_rng(seed)
    if dt in ("int32", "uint8"):
        return rng.integers(0, 100, size=shape).astype(dt)
    # random bits through float32 keeps bf16/f16 rounding out of the
    # picture: what we save is exactly what the caller held
    return np.asarray(jnp.asarray(rng.standard_normal(shape),
                                  jnp.float32).astype(dt))


_ckpt_tree = st.recursive(
    _ckpt_leaf(),
    lambda kids: st.one_of(
        st.dictionaries(st.sampled_from(list("abcdef")), kids,
                        min_size=1, max_size=3),
        st.lists(kids, min_size=1, max_size=3).map(tuple)),
    max_leaves=8)


@settings(deadline=None, max_examples=15,
          suppress_health_check=[HealthCheck.too_slow])
@given(_ckpt_tree, st.integers(0, 10 ** 6))
def test_checkpoint_roundtrip_byte_identical(tree, step):
    import tempfile
    from repro.checkpoint import io as ckpt
    with tempfile.TemporaryDirectory() as d:
        path = ckpt.save(f"{d}/snap", tree, step=step, fingerprint="prop")
        assert ckpt.verify(path, fingerprint="prop")
        assert ckpt.read_manifest(path)["step"] == step
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype),
            tree)
        back = ckpt.restore(path, like, fingerprint="prop")
        assert jax.tree.structure(tree) == jax.tree.structure(back)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            a, b = np.asarray(a), np.asarray(b)
            assert a.dtype == b.dtype and a.shape == b.shape
            assert a.tobytes() == b.tobytes()


@settings(deadline=None, max_examples=10,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(["bitflip", "truncate"]),
       st.sampled_from(["arrays", "manifest"]))
def test_checkpoint_corruption_always_detected(seed, mode, target):
    """ANY seeded single-bit flip or truncation of either snapshot file
    must fail verification — there is no corruptible byte the integrity
    pass does not cover."""
    import tempfile
    from repro.checkpoint import io as ckpt
    from repro.testing import faults
    rng = np.random.default_rng(seed)
    tree = {"w": rng.standard_normal((3, 5)).astype(np.float32),
            "b": np.asarray(jnp.asarray(rng.standard_normal(4),
                                        jnp.float32).astype(jnp.bfloat16))}
    with tempfile.TemporaryDirectory() as d:
        path = ckpt.save(f"{d}/snap", tree, step=1)
        assert ckpt.verify(path)
        faults.corrupt_snapshot(path, mode=mode, target=target, seed=seed)
        assert not ckpt.verify(path)


@settings(deadline=None, max_examples=4,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.integers(0, 2 ** 31 - 1))
def test_checkpoint_packed_unpacked_layout_roundtrip(seed):
    """A snapshot is layout-stable: an engine running the packed relay
    and one running unpacked restore byte-identical params from the
    same file, whichever wrote it."""
    from repro import engine as engines
    from repro.configs.base import get_config
    from repro.core import packing
    from repro.core.schedule import ExecutionConfig
    import tempfile
    cfg = get_config("bert-large", "smoke").replace(dtype="float32")
    e_up = engines.create("l2l-p", cfg,
                          ExecutionConfig(n_microbatches=2), donate=False)
    e_pk = engines.create("l2l-p", cfg,
                          ExecutionConfig(n_microbatches=2,
                                          pack_params=True), donate=False)
    state = e_pk.init(jax.random.PRNGKey(seed))
    with tempfile.TemporaryDirectory() as d:
        e_pk.save(d, state, step=1)
        st_up, _ = e_up.restore(d)
        st_pk, _ = e_pk.restore(d)
    ref = packing.unpack_params(state.params)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(st_up.params)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(st_pk.params)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
