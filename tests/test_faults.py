"""Chaos suite: every failure path recovers, bit-for-bit.

Fault injection comes from ``repro.testing.faults`` (all seeded, all
reproducible).  The acceptance bars mirror the resilience contract:

* a truncated / bit-flipped snapshot is DETECTED by checksums, skipped
  by ``latest_good``, and restore falls back to the previous good one;
* a training run killed mid-run (SIGTERM graceful save, SIGKILL hard
  crash) and resumed with ``--resume auto`` reaches a final state
  bit-identical to an uninterrupted run — for both engines, pack on and
  off;
* ``skip_nonfinite`` rejects a poisoned step leaving params, optimizer
  slots and step counter bit-identical across the (G, prefetch, pack,
  K) knob grid and the baseline engine;
* an evicted serve request's recycled pages serve the next request with
  token-level parity to a solo run, and a starved page pool evicts
  pending requests at their deadline instead of wedging the scheduler.
"""
import json
import os
import signal

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import engine as engines
from repro.checkpoint import io as ckpt
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig
from repro.serve.engine import ServeConfig
from repro.testing import faults

from conftest import make_batch


def bits_equal(a, b):
    """True iff two pytrees are BIT-identical (bytes, not values — NaN
    payloads and signed zeros count)."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.asarray(x).tobytes() == np.asarray(y).tobytes()
               for x, y in zip(la, lb))


# ===========================================================================
# Checkpoint corruption: detect, skip, fall back
# ===========================================================================
@pytest.fixture(scope="module")
def ckpt_engine():
    cfg = get_config("bert-large", "smoke")
    return engines.create("l2l-p", cfg, ExecutionConfig(n_microbatches=2),
                          donate=False)


@pytest.mark.parametrize("mode,target", [
    ("bitflip", "arrays"),
    ("truncate", "arrays"),
    ("bitflip", "manifest"),
])
def test_corruption_detected(tmp_path, ckpt_engine, mode, target):
    eng = ckpt_engine
    state = eng.init(jax.random.PRNGKey(0))
    d = str(tmp_path)
    eng.save(d, state, step=3)
    path = ckpt.snapshot_path(d, 3)
    assert ckpt.verify(path, fingerprint=eng.state_fingerprint())
    faults.corrupt_snapshot(path, mode=mode, target=target, seed=1)
    assert not ckpt.verify(path, fingerprint=eng.state_fingerprint())


def test_corrupt_newest_falls_back_to_previous_good(tmp_path, ckpt_engine):
    eng = ckpt_engine
    state5 = eng.init(jax.random.PRNGKey(0))
    cfg = eng.model.cfg
    batch = make_batch(cfg, 4, 16)
    state7, _ = eng.train_step(state5, batch)
    d = str(tmp_path)
    eng.save(d, state5, step=5)
    eng.save(d, state7, step=7)

    # disk rot hits the newest snapshot
    faults.corrupt_snapshot(ckpt.snapshot_path(d, 7), mode="bitflip", seed=3)
    assert ckpt.latest_step(d) == 7                    # it still exists...
    fp = eng.state_fingerprint()
    assert ckpt.latest_good(d, fingerprint=fp) == 5    # ...but is skipped
    restored, step = eng.restore(d)
    assert step == 5
    assert bits_equal(restored.params, state5.params)

    # the remaining snapshot is half-written: nothing left to restore
    faults.corrupt_snapshot(ckpt.snapshot_path(d, 5), mode="truncate",
                            seed=4)
    assert ckpt.latest_good(d, fingerprint=fp) is None
    with pytest.raises(AssertionError, match="no verifiable checkpoint"):
        eng.restore(d)


def test_fingerprint_mismatch_rejected(tmp_path, ckpt_engine):
    """A snapshot from a different model/optimizer layout never verifies
    against this engine's fingerprint — a wrong --ckpt-dir can't load
    garbage into the wrong architecture."""
    eng = ckpt_engine
    state = eng.init(jax.random.PRNGKey(0))
    d = str(tmp_path)
    eng.save(d, state, step=1)
    path = ckpt.snapshot_path(d, 1)
    assert ckpt.verify(path, fingerprint=eng.state_fingerprint())
    assert not ckpt.verify(path, fingerprint="other-arch:L99:d1:v1:opt=sgd")
    assert ckpt.latest_good(d, fingerprint="other:L1:d1:v1:opt=x") is None


def test_retention_prunes_and_sweeps_debris(tmp_path):
    d = str(tmp_path)
    tree = {"w": jnp.arange(4, dtype=jnp.float32)}
    for s in (1, 2, 3, 4):
        ckpt.save_train_state(d, tree, {"m": tree}, step=s, keep_last=2)
    assert ckpt._snapshot_steps(d, "ckpt") == [3, 4]
    # a crashed save leaves staging debris; the next prune sweeps it
    os.makedirs(os.path.join(d, ".tmp-ckpt_9.12345"))
    ckpt.prune(d, keep_last=0)
    assert not [f for f in os.listdir(d) if f.startswith(".tmp-")]
    assert ckpt._snapshot_steps(d, "ckpt") == [3, 4]   # keep_last<=0: no prune


def test_atomic_save_overwrite_keeps_snapshot_complete(tmp_path):
    """Re-saving the same step replaces the snapshot atomically; the
    result always verifies (never a half-merged directory)."""
    path = str(tmp_path / "snap")
    ckpt.save(path, {"a": jnp.ones(8)}, step=1)
    ckpt.save(path, {"a": jnp.zeros(8)}, step=1)       # overwrite in place
    assert ckpt.verify(path)
    back = ckpt.restore(path, {"a": jnp.ones(8)})
    assert float(np.sum(np.asarray(back["a"]))) == 0.0


# ===========================================================================
# Preemption: kill mid-run, resume, bit-identical final state
# ===========================================================================
TINY = ["--arch", "bert-large", "--variant", "smoke",
        "--d-model", "32", "--n-layers", "2",
        "--batch", "4", "--seq", "16", "--ub", "2",
        "--steps", "6", "--log-every", "1", "--seed", "3"]

# both engines and both pack settings appear in tier-1; the remaining
# cross combinations ride the slow lane
KILL_COMBOS = [
    pytest.param(["--engine", "l2l-p"], id="l2l-p"),
    pytest.param(["--engine", "l2l", "--no-eager", "--pack"],
                 id="l2l-pack"),
    pytest.param(["--engine", "l2l-p", "--pack", "--group", "2"],
                 id="l2l-p-pack-g2", marks=pytest.mark.slow),
    pytest.param(["--engine", "l2l", "--no-eager"],
                 id="l2l", marks=pytest.mark.slow),
]


def _final_checksums(ckpt_dir, argv):
    """Run the driver to completion in ``ckpt_dir`` and return the final
    snapshot's per-array crc32 list."""
    faults.run_train(argv + ["--ckpt-dir", ckpt_dir])
    return faults.snapshot_checksums(ckpt_dir, step=6)


@pytest.mark.parametrize("combo", KILL_COMBOS)
def test_sigterm_resume_bit_identical(tmp_path, combo):
    """SIGTERM mid-run: the driver finishes the in-flight step, saves,
    drops a PREEMPTED marker and exits 0; ``--resume auto`` then replays
    the remaining steps to a final state bit-identical to a run that was
    never interrupted."""
    ref = _final_checksums(str(tmp_path / "ref"), TINY + combo)

    d = str(tmp_path / "killed")
    proc = faults.launch_train(
        TINY + combo + ["--ckpt-dir", d, "--ckpt-every", "2",
                        "--step-delay-ms", "150", "--resume", "auto"])
    rc, out = faults.kill_at_step(proc, 2, sig=signal.SIGTERM)
    assert rc == 0, f"graceful preemption should exit 0:\n{out}"
    marker = os.path.join(d, "PREEMPTED.json")
    assert os.path.exists(marker)
    with open(marker) as f:
        info = json.load(f)
    assert 0 < info["step"] < 6 and info["signal"] == signal.SIGTERM
    # the snapshot written on the way out is crash-consistent
    assert ckpt.latest_good(d) == info["step"]

    out2 = faults.run_train(TINY + combo + [
        "--ckpt-dir", d, "--ckpt-every", "2", "--resume", "auto"])
    assert f"resumed from {d} at step {info['step']}" in out2
    assert not os.path.exists(marker)          # clean completion clears it
    assert faults.snapshot_checksums(d, step=6) == ref


@pytest.mark.slow
def test_sigkill_resume_bit_identical(tmp_path):
    """SIGKILL (no handler can run): the run loses everything since its
    last periodic snapshot but resumes from it to the same final bits."""
    combo = ["--engine", "l2l-p"]
    ref = _final_checksums(str(tmp_path / "ref"), TINY + combo)

    d = str(tmp_path / "killed")
    proc = faults.launch_train(
        TINY + combo + ["--ckpt-dir", d, "--ckpt-every", "2",
                        "--step-delay-ms", "150"])
    rc, _ = faults.kill_at_step(proc, 3, sig=signal.SIGKILL)
    assert rc != 0                              # hard crash
    assert not os.path.exists(os.path.join(d, "PREEMPTED.json"))
    good = ckpt.latest_good(d)
    assert good is not None and good < 6        # periodic snapshot survives

    faults.run_train(TINY + combo + [
        "--ckpt-dir", d, "--ckpt-every", "2", "--resume", "auto"])
    assert faults.snapshot_checksums(d, step=6) == ref


def test_resume_explicit_dir_without_checkpoint_errors(tmp_path):
    proc = faults.launch_train(
        TINY + ["--resume", str(tmp_path / "nowhere")])
    assert proc.stdout is not None
    out = proc.stdout.read()
    proc.stdout.close()
    assert proc.wait(timeout=120) != 0
    assert "no verifiable checkpoint" in out


# ===========================================================================
# Anomaly sentinel: skip_nonfinite across the knob grid
# ===========================================================================
GRID = [
    pytest.param("baseline", dict(), id="baseline"),
    pytest.param("l2l-p", dict(), id="l2l-p"),
    pytest.param("l2l-p", dict(layers_per_relay=2, prefetch_depth=1,
                               pack_params=True, stash_every=2),
                 id="l2l-p-g2-k1-pack-K2"),
    pytest.param("l2l", dict(), id="l2l-alg3"),
]


@pytest.mark.parametrize("name,knobs", GRID)
def test_skip_nonfinite_bit_identity(make_engine, name, knobs):
    """A poisoned batch (one NaN in the loss mask => every gradient
    non-finite) must leave the ENTIRE TrainState — params, optimizer
    slots, step counter — bit-identical, and be counted; a clean batch
    afterwards advances normally."""
    eng = make_engine(name, exec_cfg=ExecutionConfig(
        n_microbatches=2, skip_nonfinite=True, **knobs))
    cfg = eng.model.cfg
    state = eng.init(jax.random.PRNGKey(0))
    clean = make_batch(cfg, 4, 16)
    state, m = eng.train_step(state, clean)
    assert int(m["skipped_steps"]) == 0

    poisoned = faults.poison_batch(clean, seed=5)
    after, m = eng.train_step(state, poisoned)
    assert int(m["skipped_steps"]) == 1
    assert not np.isfinite(float(m["loss"]))
    assert bits_equal(state, after)             # full pass-through

    state2, m = eng.train_step(after, clean)
    assert int(m["skipped_steps"]) == 0
    assert int(state2.step) == int(state.step) + 1
    assert np.isfinite(float(m["loss"]))


def test_skip_nonfinite_off_poisons_state(make_engine):
    """Control: without the sentinel the NaN propagates into params —
    proving the test above exercises a real failure path."""
    eng = make_engine("l2l-p", exec_cfg=ExecutionConfig(n_microbatches=2))
    cfg = eng.model.cfg
    state = eng.init(jax.random.PRNGKey(0))
    poisoned = faults.poison_batch(make_batch(cfg, 4, 16), seed=5)
    after, _ = eng.train_step(state, poisoned)
    leaves = [np.asarray(x) for x in jax.tree.leaves(after.params)]
    assert any(not np.isfinite(x).all() for x in leaves)


# ===========================================================================
# Serve graceful degradation: deadlines, eviction, starvation
# ===========================================================================
@pytest.fixture(scope="module")
def serve_setup():
    cfg = get_config("granite-3-8b", "smoke")
    eng = engines.create("l2l", cfg, ExecutionConfig())
    params = eng.model.init_params(jax.random.PRNGKey(0))
    return cfg, eng, params


def _scfg(**kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("page_size", 8)
    kw.setdefault("n_pages", 8)
    kw.setdefault("max_seq", 32)
    kw.setdefault("prefill_chunk", 1)
    return ServeConfig(**kw)


def test_evicted_request_pages_reused_with_parity(serve_setup):
    """Evict a mid-prefill request at its tick deadline, then serve a
    fresh request through the SAME recycled slot/pages: its tokens must
    equal a solo run on a pristine pool (claim-reset hygiene)."""
    cfg, eng, params = serve_setup
    rng = np.random.RandomState(1)
    pA = rng.randint(0, cfg.vocab_size, size=(4,))
    pB = rng.randint(0, cfg.vocab_size, size=(4,))

    srv = eng.serve_session(params, _scfg())
    A = srv.submit(pA, 6, seed=7, ttl_ticks=3)
    srv.run()
    assert A.evicted and not A.done
    st = srv.stats()
    assert st["evicted"] == 1 and st["free_slots"] == 2
    assert st["free_pages"] == 8 and st["reserved_pages"] == 0

    B = srv.submit(pB, 6, seed=9)
    srv.run()
    assert B.done and len(B.generated) == 6

    solo = eng.serve_session(params, _scfg())
    B2 = solo.submit(pB, 6, seed=9)
    solo.run()
    assert B.generated == B2.generated          # token-level parity


def test_mid_decode_eviction_releases_everything(serve_setup):
    """A deadline that fires mid-decode (after tokens were produced)
    still releases the slot and every claimed page."""
    cfg, eng, params = serve_setup
    rng = np.random.RandomState(2)
    srv = eng.serve_session(params, _scfg())
    r = srv.submit(rng.randint(0, cfg.vocab_size, size=(4,)), 20,
                   seed=1, ttl_ticks=8)
    srv.run()
    assert r.evicted and 0 < len(r.generated) < 20
    st = srv.stats()
    assert st["free_pages"] == 8 and st["free_slots"] == 2
    assert st["reserved_pages"] == 0


def test_page_pool_starvation_evicts_pending(serve_setup):
    """With the free pool stolen dry, admission blocks; the pending
    request's deadline evicts it instead of wedging the scheduler, and
    healing the pool lets a new request through."""
    cfg, eng, params = serve_setup
    rng = np.random.RandomState(3)
    srv = eng.serve_session(params, _scfg())
    stolen = faults.steal_pages(srv.scheduler, 8)   # leak everything
    r = srv.submit(rng.randint(0, cfg.vocab_size, size=(4,)), 4,
                   seed=1, ttl_ticks=2)
    assert r.slot < 0                               # cannot be admitted
    srv.run()
    assert r.evicted and srv.stats()["evicted"] == 1

    faults.restore_pages(srv.scheduler, stolen)     # the leak heals
    r2 = srv.submit(rng.randint(0, cfg.vocab_size, size=(4,)), 4, seed=2)
    srv.run()
    assert r2.done and len(r2.generated) == 4


def test_bounded_admission_rejects_overflow(serve_setup):
    """max_pending bounds the queue AFTER eager admission: with 2 slots
    and a queue of 1, the 4th and 5th submits are rejected, counted,
    and never served; everything admitted completes."""
    cfg, eng, params = serve_setup
    rng = np.random.RandomState(4)
    srv = eng.serve_session(params, _scfg(max_pending=1))
    reqs = [srv.submit(rng.randint(0, cfg.vocab_size, size=(4,)), 3,
                       seed=i) for i in range(5)]
    assert [r.status for r in reqs] == \
        ["active", "active", "queued", "rejected", "rejected"]
    srv.run()
    assert [r.status for r in reqs] == \
        ["done", "done", "done", "rejected", "rejected"]
    st = srv.stats()
    assert st["rejected"] == 2 and st["finished"] == 3
    assert all(len(r.generated) == 3 for r in reqs if r.done)


def test_serve_driver_reports_degradation_counters():
    """The continuous driver's final stats line carries done/rejected/
    evicted so operators see degradation without scraping logs."""
    from repro.launch.serve import main
    reqs = main(["--arch", "granite-3-8b", "--variant", "smoke",
                 "--requests", "5", "--max-batch", "2",
                 "--prompt-len", "8", "--gen", "4",
                 "--max-pending", "1"])
    statuses = [r.status for r in reqs]
    assert statuses.count("rejected") == 2
    assert statuses.count("done") == 3


# ===========================================================================
# Storage tier (tiers=3): rot detection, retry, rebuild, degradation
# ===========================================================================
# deeper single-fault coverage lives in tests/test_tierstore.py; this
# section is the chaos-bar subset — every disk failure mode recovers (or
# degrades) WITHOUT aborting the step loop, proven bit-for-bit
import errno
import time

from repro.core.tierstore import SegmentStore, TierIntegrityError, \
    TierReadError


def _tier_segs(seed=0):
    rng = np.random.default_rng(seed)
    return {"float32": rng.standard_normal((4, 6)).astype(np.float32)}


def _tier_exec(root, **kw):
    kw.setdefault("n_microbatches", 2)
    return ExecutionConfig(tiers=3, tier_dir=str(root),
                           tier_backoff_s=0.001, **kw)


def test_tier_rot_detected_at_open_and_at_read(tmp_path):
    """Both verification layers fire: the whole-file crc rejects a torn
    segment at OPEN (fresh store), and the per-row crc catches a bit
    flipped AFTER open at the read that returns it."""
    st = SegmentStore(str(tmp_path))
    st.put("g0_w", _tier_segs(), step=0)
    st.open("g0_w")
    faults.corrupt_segment(st, "g0_w", seed=2)        # in-place rot
    with pytest.raises(TierIntegrityError):
        st.read_rows("g0_w", 0, 4)                    # read-time detection

    st2 = SegmentStore(str(tmp_path))
    st2.put("g1_w", _tier_segs(seed=1), step=0)
    faults.corrupt_file(st2.seg_path("g1_w", "float32"), mode="truncate")
    with pytest.raises(TierIntegrityError):
        SegmentStore(str(tmp_path)).open("g1_w")      # open-time detection


def test_tier_transient_eio_backoff_then_hard_error(tmp_path):
    """EIO is retried with exponential backoff and the run proceeds; an
    error past the retry budget (or a non-transient errno) surfaces as a
    hard TierReadError, never as silent garbage."""
    st = SegmentStore(str(tmp_path), retries=3, backoff_s=0.01)
    st.put("g0_w", _tier_segs(), step=0)
    f = faults.inject_io_error(st, fail_reads=2, err=errno.EIO)
    t0 = time.monotonic()
    out = st.read_rows("g0_w", 0, 4)
    assert time.monotonic() - t0 >= 0.01 + 0.02       # backoff 1x, then 2x
    np.testing.assert_array_equal(out["float32"], _tier_segs()["float32"])
    assert f.raised == 2 and st.metrics["retries"] == 2

    faults.inject_io_error(st, fail_reads=99, err=errno.EIO,
                           persistent=True)
    with pytest.raises(TierReadError, match="4 attempt"):
        st.read_rows("g0_w", 0, 4)


def test_tier_step_loop_survives_rot_via_rebuild(make_engine, tmp_path):
    """The full contract: seeded rot lands on a live segment mid-run and
    the step loop COMPLETES — quarantine + rebuild from the newest good
    checkpoint, final state bit-identical to an undisturbed tier run."""
    cfg = get_config("bert-large", "smoke").replace(dtype="float32",
                                                    n_layers=3)
    batch = make_batch(cfg, 4, 16)

    def run(root, rot):
        eng = engines.create("l2l-p", cfg, _tier_exec(root),
                             donate=False)
        state = eng.init(jax.random.PRNGKey(0))
        for i in range(3):
            eng.save(str(tmp_path / "ckpt"), state)
            if i == 2 and rot:
                # opt segments re-materialize from disk every step, so
                # rot here is read (and must be healed) immediately
                faults.corrupt_segment(eng.tier.store, "g0_opt", seed=9)
            state, _ = eng.train_step(state, batch)
        return eng.tier.stage_in(state), eng.tier.metrics

    ref, _ = run(tmp_path / "a", rot=False)
    got, metrics = run(tmp_path / "b", rot=True)
    assert metrics["rebuilt_segments"] >= 1
    assert metrics["quarantined"] >= 1
    assert bits_equal(ref, got)


def test_tier_budget_demotes_instead_of_oom(make_engine, tmp_path):
    """An over-subscribed host budget demotes the coldest layer rows to
    disk and keeps training; latency injected on every disk read slows
    the run but changes no bits."""
    cfg = get_config("bert-large", "smoke").replace(dtype="float32",
                                                    n_layers=4)
    batch = make_batch(cfg, 4, 16)
    eng = engines.create(
        "l2l-p", cfg,
        _tier_exec(tmp_path / "t", host_budget_bytes=2 << 20,
                   prefetch_depth=1), donate=False)
    ref = engines.create("l2l-p", cfg,
                         ExecutionConfig(n_microbatches=2), donate=False)
    faults.inject_io_latency(eng.tier.store, delay_s=0.002,
                             jitter_s=0.001, seed=4)
    s_t = eng.init(jax.random.PRNGKey(0))
    s_r = ref.init(jax.random.PRNGKey(0))
    for _ in range(2):
        s_t, _ = eng.train_step(s_t, batch)
        s_r, _ = ref.train_step(s_r, batch)
    m = eng.tier.metrics
    assert 0 < m["demoted_layers"] < 4        # partial demotion, no OOM
    assert m["reads"] > 0
    assert bits_equal(eng.tier.stage_in(s_t), s_r)


def test_tier_async_stage_in_under_forced_latency(make_engine, tmp_path):
    """The read-ahead ring's async stage-in: stage_out schedules the
    next window's cold-segment fetches in the background, so forced
    per-read disk latency lands while the main thread is between steps;
    the next stage_in consumes the finished futures (counted as
    async_stage_hits) and every bit still matches a strictly
    synchronous (depth 0, no latency) tier run."""
    cfg = get_config("bert-large", "smoke").replace(dtype="float32",
                                                    n_layers=3)
    batch = make_batch(cfg, 4, 16)
    eng = engines.create(
        "l2l-p", cfg,
        _tier_exec(tmp_path / "async", prefetch_depth=1), donate=False)
    ref = engines.create(
        "l2l-p", cfg,
        _tier_exec(tmp_path / "sync"), donate=False)
    fault = faults.inject_io_latency(eng.tier.store, delay_s=0.003,
                                     jitter_s=0.002, seed=11)
    s_a = eng.init(jax.random.PRNGKey(0))
    s_r = ref.init(jax.random.PRNGKey(0))
    for _ in range(3):
        s_a, _ = eng.train_step(s_a, batch)
        s_r, _ = ref.train_step(s_r, batch)
    m = eng.tier.metrics
    assert fault.delayed > 0                      # latency really fired
    assert m["async_stage_hits"] > 0              # background fetches won
    assert m["async_stage_misses"] == 0           # ...every single window
    assert ref.tier.metrics["async_stage_hits"] == 0   # depth 0 = sync
    assert bits_equal(eng.tier.stage_in(s_a), ref.tier.stage_in(s_r))
