"""Pallas relay transport (``ExecutionConfig.transport``) bit-identity.

``transport="pallas"`` routes every relay slot move — stream-in of the
next stop's weights and the boundary/grad/update write-back — through
the ``kernels/relay_copy`` double-buffered ``make_async_copy`` DMA
pipeline instead of scan-boundary ``device_put``s.  The move is a pure
copy, so EVERY output (loss, grads, updated params, optimizer state,
prefill logits, decode logits and caches) must be bit-identical to
``transport="xla"`` at every schedule point.

Grid: (G, prefetch, pack, K) x (l2l, l2l-p), CPU interpret mode.  A
representative diagonal runs in tier-1; the remaining cross terms are
``slow`` and run in the CI transport-smoke job.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro import engine as engines
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig
from repro.optim import adam


def _assert_bit_identical(a, b, ctx=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), ctx
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y)), ctx


def _train_outputs(name, transport, *, group, prefetch, pack, stash):
    cfg = get_config("bert-large", "smoke").replace(dtype="float32",
                                                    n_layers=5)
    ec = ExecutionConfig(n_microbatches=2, layers_per_relay=group,
                         prefetch_depth=prefetch, pack_params=pack,
                         stash_every=stash, transport=transport)
    eng = engines.create(name, cfg, ec, optimizer=adam(lr=1e-3),
                         donate=False)
    batch = make_batch(cfg, 2, 8)
    params = eng.model.init_params(jax.random.PRNGKey(0))
    loss, grads = eng.grads(params, batch)
    state, m = eng.train_step(eng.init(jax.random.PRNGKey(0)), batch)
    return (loss, grads, state.params, state.opt_state, m["loss"])


# every knob at both levels, engine x knob interactions on the diagonal;
# the full cross product rides in the slow grid below
FAST_GRID = [
    ("l2l", 1, 0, False, 1),
    ("l2l", 2, 2, True, 3),      # grouped + ring + packed + stash at once
    ("l2l-p", 1, 1, True, 1),
    ("l2l-p", 2, 0, False, 2),
]
FULL_GRID = [t for t in itertools.product(
    ("l2l", "l2l-p"), (1, 2), (0, 2), (False, True), (1, 3))
    if t not in FAST_GRID]


@pytest.mark.parametrize("name,group,prefetch,pack,stash", FAST_GRID)
def test_train_bit_identical(name, group, prefetch, pack, stash):
    """Grads, trailing/eager updates, and opt state are exactly equal."""
    ox = _train_outputs(name, "xla", group=group, prefetch=prefetch,
                        pack=pack, stash=stash)
    op = _train_outputs(name, "pallas", group=group, prefetch=prefetch,
                        pack=pack, stash=stash)
    _assert_bit_identical(ox, op, f"{name} G={group} pf={prefetch} "
                                  f"pack={pack} K={stash}")


@pytest.mark.slow
@pytest.mark.parametrize("name,group,prefetch,pack,stash", FULL_GRID)
def test_train_bit_identical_full_grid(name, group, prefetch, pack, stash):
    ox = _train_outputs(name, "xla", group=group, prefetch=prefetch,
                        pack=pack, stash=stash)
    op = _train_outputs(name, "pallas", group=group, prefetch=prefetch,
                        pack=pack, stash=stash)
    _assert_bit_identical(ox, op, f"{name} G={group} pf={prefetch} "
                                  f"pack={pack} K={stash}")


# ---------------------------------------------------------------------------
# serve paths: prefill + decode tick under the weight-streaming relay
# ---------------------------------------------------------------------------
def _decode_outputs(transport, *, group, prefetch, pack):
    cfg = get_config("granite-3-8b", "smoke").replace(dtype="float32")
    ec = ExecutionConfig(weight_stream=True, layers_per_relay=group,
                         prefetch_depth=prefetch, pack_params=pack,
                         transport=transport)
    eng = engines.create("l2l", cfg, ec, donate=False)
    params = eng.model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    caches, last = eng.decode_init(params, toks, 16)
    outs = [last]
    tok = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
    for i in range(3):
        logits, caches = eng.decode_step(params, caches, tok,
                                         jnp.int32(8 + i))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        outs.append(logits)
    return outs, caches


@pytest.mark.parametrize("group,prefetch,pack", [
    (1, 0, False), (2, 1, True), (1, 2, True)])
def test_prefill_decode_bit_identical(group, prefetch, pack):
    """Prefill logits and every decode tick (logits AND caches) match."""
    ox, cx = _decode_outputs("xla", group=group, prefetch=prefetch,
                             pack=pack)
    op, cp = _decode_outputs("pallas", group=group, prefetch=prefetch,
                             pack=pack)
    _assert_bit_identical((ox, cx), (op, cp),
                          f"G={group} pf={prefetch} pack={pack}")


# ---------------------------------------------------------------------------
# config plumbing + memory accounting
# ---------------------------------------------------------------------------
def test_transport_validated():
    with pytest.raises(AssertionError):
        ExecutionConfig(transport="dma")


def test_baseline_normalizes_transport():
    """Baseline has no relay, so its config drops the pallas transport —
    one cache entry, no dead kernel in the program."""
    cfg = get_config("bert-large", "smoke")
    eng = engines.create("baseline", cfg,
                         ExecutionConfig(transport="pallas"), donate=False)
    assert eng.exec_cfg.transport == "xla"


def test_memory_model_counts_double_buffer():
    """transport="pallas" adds the kernel's two in-flight DMA chunks to
    the device budget; "xla" adds nothing."""
    cfg = get_config("bert-large", "smoke")
    eng = engines.create("l2l", cfg, ExecutionConfig(transport="pallas"),
                         donate=False)
    rep_p = eng.memory_estimate(batch=2, seq=8)
    rep_x = eng.memory_estimate(batch=2, seq=8, transport="xla")
    assert rep_p.transport_buffer > 0
    assert rep_x.transport_buffer == 0
    assert (rep_p.total_device - rep_x.total_device
            == rep_p.transport_buffer)
    from repro.serve.engine import ServeConfig
    scfg = ServeConfig(max_batch=2, page_size=8, n_pages=8, max_seq=16)
    sp = eng.serve_memory_estimate(scfg, weight_stream=True)
    sx = eng.serve_memory_estimate(scfg, weight_stream=True,
                                   transport="xla")
    assert sp.transport_buffer > 0 and sx.transport_buffer == 0
