"""Continuous-batching serve engine: paged KV, scheduler, token parity.

The acceptance bar is BIT-identity: greedy tokens produced through the
scheduler path — paged pool, join/leave churn, chunked prefill — must
equal the single-batch ``decode_init``/``decode_step`` reference at the
same batch shape, across the relay knob grid and cache families.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import engine as engines
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig
from repro.models.common import ParamSpec
from repro.serve.engine import ServeConfig
from repro.serve.paged_kv import GroupPages, gather_view, scatter_new
from repro.serve.sampling import sample
from repro.serve.scheduler import Scheduler


# ===========================================================================
# scheduler / allocator units (pure host)
# ===========================================================================
def _sched(**kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("page_size", 4)
    kw.setdefault("n_pages", 4)
    kw.setdefault("max_seq", 16)
    return Scheduler(**kw)


def _drain_request(s, req):
    """Step the scheduler alone (no model): feed dummy sampled zeros."""
    while not req.done:
        plan = s.plan_tick()
        assert plan is not None
        s.record(np.zeros(s.max_batch, np.int32))


def test_reservation_blocks_admission_until_pages_free():
    # each request needs ceil((6 + 7) / 4) = 4 pages; pool holds 4
    s = _sched(n_pages=4, max_seq=32)
    a = s.submit(np.zeros(6, np.int32), 8)
    b = s.submit(np.zeros(6, np.int32), 8)
    s.plan_tick()
    assert a.slot >= 0 and b.slot < 0          # b waits despite free slot
    assert s.reserved + (s.n_pages - len(s.free_pages)) == 4
    s.record(np.zeros(2, np.int32))
    _drain_request(s, a)
    assert a.done and len(a.generated) == 8
    s.plan_tick()                              # a's pages are back -> b in
    assert b.slot >= 0
    s.record(np.zeros(2, np.int32))


def test_pages_claimed_lazily_and_freed_on_finish():
    s = _sched(n_pages=8, max_seq=16)
    r = s.submit(np.zeros(2, np.int32), 9)     # needs 3 pages eventually
    plan = s.plan_tick()
    # first tick touches only page 0 of the slot: exactly one claim
    assert (plan.new_pages >= 0).sum() == 1
    assert len(s.free_pages) == 7
    s.record(np.zeros(2, np.int32))
    _drain_request(s, r)
    assert len(s.free_pages) == 8 and s.reserved == 0
    assert (s.table == -1).all()


def test_window_ring_reuses_pages():
    # window=8 -> 2 pages per slot cap, positions wrap past max_seq
    s = _sched(n_pages=4, max_seq=8, window=8)
    r = s.submit(np.zeros(6, np.int32), 12)    # 17 positions >> 8
    while not r.done:
        s.plan_tick()
        s.record(np.zeros(2, np.int32))
    assert len(r.generated) == 12              # ring never runs out
    assert len(s.free_pages) == 4


def test_prompt_exceeding_capacity_rejected_without_window():
    s = _sched(max_seq=8)
    with pytest.raises(ValueError):
        s.submit(np.zeros(8, np.int32), 4)
    # the same prompt is fine under a ring
    _sched(max_seq=8, window=8).submit(np.zeros(8, np.int32), 4)


def test_context_exhaustion_finishes_request_early():
    s = _sched(max_seq=8)
    r = s.submit(np.zeros(4, np.int32), 100)
    while not r.done:
        s.plan_tick()
        s.record(np.zeros(2, np.int32))
    # positions 0..7 only: 4 prompt + 4 generated tokens fit
    assert len(r.generated) == 5               # sampled at caching 8th pos


def test_fifo_admission_and_slot_reuse():
    s = _sched(max_batch=2, n_pages=8, max_seq=16)
    rs = [s.submit(np.zeros(2, np.int32), 3) for _ in range(5)]
    for _ in range(64):
        if s.idle:
            break
        s.plan_tick()
        s.record(np.zeros(2, np.int32))
    assert all(r.done for r in rs)
    # FIFO: completion order follows submission order
    assert [r.rid for r in sorted(rs, key=lambda r: r.t_done)] == \
        [r.rid for r in rs]


# ===========================================================================
# paged pool gather/scatter (micro, one layer)
# ===========================================================================
def _toy_pages(B=2, S=8):
    spec = {"k": ParamSpec((B, S, 2), ("batch", "seq", "kv"), "zeros"),
            "pos": ParamSpec((B, S), ("batch", "seq"), "zeros"),
            "h": ParamSpec((B, 3), ("batch", "ffn"), "zeros")}
    return GroupPages(spec, {"k": True, "pos": True, "h": False})


def test_gather_view_masks_unmapped_pages():
    gp = _toy_pages()
    ps, n_pages = 4, 4
    pool = {"k": jnp.arange(n_pages * ps * 2, dtype=jnp.float32)
                    .reshape(n_pages, ps, 2),
            "pos": jnp.tile(jnp.arange(ps), (n_pages, 1)).astype(jnp.int32),
            "h": jnp.ones((2, 3))}
    table = jnp.array([[2, -1], [0, 1]], jnp.int32)
    view = gather_view(pool, gp, table, ps)
    assert view["k"].shape == (2, 8, 2) and view["pos"].shape == (2, 8)
    # mapped pages read their physical page verbatim
    np.testing.assert_array_equal(view["k"][0, :4], pool["k"][2])
    np.testing.assert_array_equal(view["k"][1, :4], pool["k"][0])
    np.testing.assert_array_equal(view["k"][1, 4:], pool["k"][1])
    # unmapped logical page: pos forced to -1 (attention's invalid marker)
    assert (view["pos"][0, 4:] == -1).all()
    assert (view["pos"][1] >= 0).all()
    # per-slot leaves pass through untouched
    np.testing.assert_array_equal(view["h"], pool["h"])


def test_scatter_writes_only_ticked_slots_and_drops_invalid():
    gp = _toy_pages()
    ps = 4
    pool = {"k": jnp.zeros((4, ps, 2)),
            "pos": -jnp.ones((4, ps), jnp.int32),
            "h": jnp.zeros((2, 3))}
    table = jnp.array([[2, -1], [0, 1]], jnp.int32)
    view = gather_view(pool, gp, table, ps)
    view = {"k": view["k"].at[:, :].add(7.0),        # decode "wrote" stuff
            "pos": jnp.where(view["pos"] < -10, view["pos"], view["pos"]),
            "h": view["h"] + 5.0}
    view["pos"] = jnp.full((2, 8), 9, jnp.int32)
    pos = jnp.array([[1], [-1]], jnp.int32)          # row1 = padding
    active = jnp.array([True, False])
    out = scatter_new(pool, view, gp, table, pos, active)
    # row 0 slot 1 -> physical page 2 offset 1; nothing else moves
    assert float(out["k"][2, 1, 0]) == 7.0
    assert float(jnp.abs(out["k"]).sum()) == 14.0    # the one (2,) vector
    assert int(out["pos"][2, 1]) == 9
    assert int((out["pos"] == 9).sum()) == 1
    # per-slot leaf: active row takes the new value, padding keeps old
    np.testing.assert_array_equal(np.asarray(out["h"][0]), [5., 5., 5.])
    np.testing.assert_array_equal(np.asarray(out["h"][1]), [0., 0., 0.])


# ===========================================================================
# token parity: scheduler path vs single-batch reference
# ===========================================================================
def _greedy_ref(eng, params, prompt, new, live, B):
    """Reference tokens: the historical fixed-batch greedy loop with the
    prompt replicated across all B rows (same program shape as the serve
    tick, so row independence makes parity exact)."""
    toks = jnp.broadcast_to(jnp.asarray(prompt), (B, len(prompt)))
    caches, last = eng.decode_init(params, toks, live)
    tok = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
    out = [int(tok[0, 0])]
    for i in range(new - 1):
        logits, caches = eng.decode_step(params, caches, tok,
                                         jnp.int32(len(prompt) + i))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    return out


def _serve_engine(arch, exec_cfg, *, B=3, max_seq=32, chunk=1, pages=None):
    cfg = get_config(arch, "smoke")
    eng = engines.create("l2l", cfg, exec_cfg, donate=False)
    params = eng.model.init_params(jax.random.PRNGKey(0))
    scfg = ServeConfig(max_batch=B, page_size=8, n_pages=pages or 4 * B,
                       max_seq=max_seq, prefill_chunk=chunk)
    return cfg, eng, params, scfg


PARITY_CASES = [
    # the knob grid on the dense/GQA family
    ("granite-3-8b", ExecutionConfig(), 32, 1),
    ("granite-3-8b", ExecutionConfig(weight_stream=True,
                                     layers_per_relay=2, prefetch_depth=1,
                                     pack_params=True), 32, 1),
    # ring-buffer window: max_seq IS the window
    ("granite-3-8b", ExecutionConfig(decode_window=16), 16, 1),
    # chunked prefill rides the sweep as extra query rows
    ("granite-3-8b", ExecutionConfig(), 32, 4),
    # MLA compressed cache + MoE, recurrent families
    ("deepseek-v2-lite-16b", ExecutionConfig(), 32, 1),
    ("hymba-1.5b", ExecutionConfig(), 32, 1),
    ("rwkv6-1.6b", ExecutionConfig(), 32, 1),
]


@pytest.mark.parametrize("arch,exec_cfg,max_seq,chunk", PARITY_CASES,
                         ids=["dense", "dense-G2pf1pack", "window",
                              "chunked-prefill", "mla-moe", "hybrid",
                              "ssm"])
def test_scheduler_tokens_bit_identical(arch, exec_cfg, max_seq, chunk):
    B, L, NEW = 3, 8, 5
    cfg, eng, params, scfg = _serve_engine(arch, exec_cfg, B=B,
                                           max_seq=max_seq, chunk=chunk)
    prompt = np.random.RandomState(0).randint(
        0, cfg.vocab_size, size=(L,)).astype(np.int32)
    ref = _greedy_ref(eng, params, prompt, NEW, max_seq, B)
    srv = eng.serve_session(params, scfg)
    reqs = [srv.submit(prompt, NEW) for _ in range(B)]
    srv.run()
    for r in reqs:
        assert r.generated == ref, f"slot-path tokens diverged: {r.rid}"


def test_unrelated_requests_joining_and_leaving_do_not_perturb():
    """THE continuous-batching correctness bar: a request's tokens are
    identical whether it runs alone or with strangers churning through
    the other slots (row independence + paged isolation)."""
    cfg, eng, params, scfg = _serve_engine("granite-3-8b",
                                           ExecutionConfig(), B=3)
    rng = np.random.RandomState(1)
    pA = rng.randint(0, cfg.vocab_size, size=(8,)).astype(np.int32)

    srv = eng.serve_session(params, scfg)
    solo = srv.submit(pA, 10)
    srv.run()

    srv = eng.serve_session(params, scfg)
    crowded = srv.submit(pA, 10)
    srv.tick(); srv.tick()
    b = srv.submit(rng.randint(0, cfg.vocab_size, size=(5,)), 3)
    srv.tick(); srv.tick()
    c = srv.submit(rng.randint(0, cfg.vocab_size, size=(11,)), 4)
    srv.run()
    assert crowded.generated == solo.generated
    assert len(b.generated) == 3 and len(c.generated) == 4


def test_slot_and_page_recycling_through_many_requests():
    cfg, eng, params, scfg = _serve_engine("granite-3-8b",
                                           ExecutionConfig(), B=2,
                                           pages=6)
    srv = eng.serve_session(params, scfg)
    rng = np.random.RandomState(2)
    prompts = [rng.randint(0, cfg.vocab_size, size=(6,)).astype(np.int32)
               for _ in range(6)]
    refs = [_greedy_ref(eng, params, p, 4, 32, 2) for p in prompts[:2]]
    reqs = [srv.submit(p, 4) for p in prompts]
    srv.run()
    assert all(len(r.generated) == 4 for r in reqs)
    # recycled slots/pages still produce exact tokens
    assert reqs[0].generated == refs[0] and reqs[1].generated == refs[1]
    st = srv.scheduler.stats()
    assert st["free_pages"] == 6 and st["free_slots"] == 2


# ===========================================================================
# sampling
# ===========================================================================
def test_sample_greedy_is_exact_argmax():
    rng = np.random.RandomState(0)
    logits = jnp.asarray(rng.randn(5, 64).astype(np.float32))
    z = jnp.zeros(5, jnp.int32)
    toks = sample(logits, z, z, jnp.zeros(5, jnp.float32), z)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sample_stream_independent_of_batch_row():
    rng = np.random.RandomState(0)
    logits = jnp.asarray(rng.randn(4, 64).astype(np.float32))
    seeds = jnp.array([7, 7, 9, 7], jnp.int32)
    pos = jnp.array([3, 3, 3, 3], jnp.int32)
    temp = jnp.full(4, 0.8, jnp.float32)
    k = jnp.zeros(4, jnp.int32)
    row_logits = jnp.broadcast_to(logits[0], (4, 64))
    toks = np.asarray(sample(row_logits, seeds, pos, temp, k))
    assert toks[0] == toks[1] == toks[3]       # same (seed, pos) stream
    # different position advances the stream
    toks2 = np.asarray(sample(row_logits, seeds, pos + 1, temp, k))
    assert (toks != toks2).any()


def test_sample_top_k_restricts_support():
    rng = np.random.RandomState(3)
    logits = jnp.asarray(rng.randn(8, 100).astype(np.float32))
    top5 = np.asarray(jnp.argsort(-logits, axis=-1)[:, :5])
    temp = jnp.full(8, 1.5, jnp.float32)
    k5 = jnp.full(8, 5, jnp.int32)
    for trial in range(5):
        seeds = jnp.full(8, trial, jnp.int32)
        toks = np.asarray(sample(logits, seeds, seeds, temp, k5))
        for b in range(8):
            assert toks[b] in top5[b]


def test_serve_temperature_matches_seeded_rerun():
    cfg, eng, params, scfg = _serve_engine("granite-3-8b",
                                           ExecutionConfig(), B=2)
    p = np.random.RandomState(4).randint(0, cfg.vocab_size,
                                         size=(6,)).astype(np.int32)
    outs = []
    for neighbour_first in (False, True):
        srv = eng.serve_session(params, scfg)
        if neighbour_first:                    # different slot assignment
            srv.submit(p[::-1].copy(), 3)
        r = srv.submit(p, 6, temperature=0.9, top_k=8, seed=123)
        srv.run()
        outs.append(r.generated)
    assert outs[0] == outs[1]


# ===========================================================================
# facade / config validation
# ===========================================================================
def test_serve_session_validates_shapes():
    cfg = get_config("granite-3-8b", "smoke")
    eng = engines.create("l2l", cfg, ExecutionConfig(decode_window=16),
                         donate=False)
    params = eng.model.init_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="decode_window"):
        eng.serve_session(params, ServeConfig(max_seq=32, page_size=8))
    with pytest.raises(ValueError, match="divide"):
        eng.serve_session(params, ServeConfig(max_seq=16, page_size=5))
    with pytest.raises(ValueError, match="n_pages"):
        eng.serve_session(params, ServeConfig(max_seq=16, page_size=2,
                                              n_pages=4))


def test_serve_session_rejects_audio():
    cfg = get_config("whisper-base", "smoke")
    eng = engines.create("l2l", cfg, ExecutionConfig(), donate=False)
    params = eng.model.init_params(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        eng.serve_session(params, ServeConfig())


def test_recurrent_families_force_single_token_prefill():
    cfg = get_config("rwkv6-1.6b", "smoke")
    eng = engines.create("l2l", cfg, ExecutionConfig(), donate=False)
    params = eng.model.init_params(jax.random.PRNGKey(0))
    srv = eng.serve_session(params, ServeConfig(max_batch=2, page_size=8,
                                                n_pages=8, max_seq=16,
                                                prefill_chunk=4))
    assert srv.cfg.prefill_chunk == 1
