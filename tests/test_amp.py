"""Mixed precision (fp16 compute + dynamic loss scaling) — the paper's
named future work, adapted to L2L's eager per-layer updates."""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.configs.base import get_config
from repro.core import l2l
from repro.core.schedule import ExecutionConfig
from repro.models.model import LayeredModel
from repro.optim import adam


def test_fp16_training_with_dynamic_loss_scale():
    cfg = get_config("bert-large", "smoke").replace(dtype="float16")
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    ec = ExecutionConfig(n_microbatches=2, loss_scale_init=2.0 ** 15,
                         loss_scale_growth=50)
    opt = adam(3e-4)
    step = jax.jit(l2l.make_train_step(model, opt, ec))
    st = l2l.init_opt_state(opt, params, ec)
    losses, scales, nonfinite = [], [], []
    for i in range(10):
        batch = make_batch(cfg, 4, 16, seed=i, dtype=jnp.float16)
        params, st, m = step(params, st, batch)
        losses.append(float(m["loss"]))
        scales.append(float(m["loss_scale"]))
        nonfinite.append(int(m["nonfinite_layers"]))
    # losses stay finite; the scale adapts DOWN from the too-large init
    # (fp16 grads overflow at 2^15) and stabilizes (no more bad layers)
    assert all(jnp.isfinite(jnp.asarray(losses)))
    assert scales[-1] < scales[0]
    assert nonfinite[-1] == 0
    # params stayed finite fp16
    assert all(jnp.isfinite(l.astype(jnp.float32)).all()
               for l in jax.tree.leaves(params))


def test_amp_with_safe_scale_matches_plain_update():
    """fp32 compute + a modest scale: identical updates to no-AMP."""
    cfg = get_config("bert-large", "smoke").replace(dtype="float32")
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 16)
    opt = adam(1e-3)
    ec0 = ExecutionConfig(n_microbatches=2)
    ec1 = ExecutionConfig(n_microbatches=2, loss_scale_init=1024.0)
    p0, _, _ = jax.jit(l2l.make_train_step(model, opt, ec0))(
        params, l2l.init_opt_state(opt, params, ec0), batch)
    p1, _, _ = jax.jit(l2l.make_train_step(model, opt, ec1))(
        params, l2l.init_opt_state(opt, params, ec1), batch)
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p0, p1)))
    assert err < 1e-5


def test_overflow_skips_update_and_halves_scale():
    """Inject an overflow via an absurd scale: params must be unchanged
    and the scale halved."""
    cfg = get_config("bert-large", "smoke").replace(dtype="float16")
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 16, dtype=jnp.float16)
    opt = adam(1e-3)
    ec = ExecutionConfig(n_microbatches=2, loss_scale_init=2.0 ** 30)
    step = jax.jit(l2l.make_train_step(model, opt, ec))
    st = l2l.init_opt_state(opt, params, ec)
    new_p, new_st, m = step(params, st, batch)
    assert int(m["nonfinite_layers"]) > 0
    assert float(new_st["loss_scale"]["scale"]) == 2.0 ** 29
    diff = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))),
        params["groups"], new_p["groups"])))
    assert diff == 0.0, "overflowed layers must skip their update"
