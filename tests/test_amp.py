"""Mixed precision (fp16 compute + dynamic loss scaling) — the paper's
named future work, adapted to L2L's eager per-layer updates — driven
through the Engine facade (the loss scale rides in TrainState)."""
import jax
import jax.numpy as jnp

from conftest import make_batch
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig
from repro.optim import adam


def test_fp16_training_with_dynamic_loss_scale(make_engine):
    eng = make_engine("l2l-p", dtype="float16", optimizer=adam(3e-4),
                      exec_cfg=ExecutionConfig(n_microbatches=2,
                                               loss_scale_init=2.0 ** 15,
                                               loss_scale_growth=50))
    cfg = eng.model.cfg
    state = eng.init(jax.random.PRNGKey(0))
    assert state.loss_scale is not None
    losses, scales, nonfinite = [], [], []
    for i in range(10):
        batch = make_batch(cfg, 4, 16, seed=i, dtype=jnp.float16)
        state, m = eng.train_step(state, batch)
        losses.append(float(m["loss"]))
        scales.append(float(m["loss_scale"]))
        nonfinite.append(int(m["nonfinite_layers"]))
    # losses stay finite; the scale adapts DOWN from the too-large init
    # (fp16 grads overflow at 2^15) and stabilizes (no more bad layers)
    assert all(jnp.isfinite(jnp.asarray(losses)))
    assert scales[-1] < scales[0]
    assert nonfinite[-1] == 0
    assert float(state.loss_scale["scale"]) == scales[-1]
    # params stayed finite fp16
    assert all(jnp.isfinite(l.astype(jnp.float32)).all()
               for l in jax.tree.leaves(state.params))


def test_amp_with_safe_scale_matches_plain_update(make_engine):
    """fp32 compute + a modest scale: identical updates to no-AMP."""
    cfg = get_config("bert-large", "smoke").replace(dtype="float32")
    batch = make_batch(cfg, 4, 16)
    e0 = make_engine("l2l-p", optimizer=adam(1e-3),
                     exec_cfg=ExecutionConfig(n_microbatches=2))
    e1 = make_engine("l2l-p", optimizer=adam(1e-3),
                     exec_cfg=ExecutionConfig(n_microbatches=2,
                                              loss_scale_init=1024.0))
    s0, _ = e0.train_step(e0.init(jax.random.PRNGKey(0)), batch)
    s1, _ = e1.train_step(e1.init(jax.random.PRNGKey(0)), batch)
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), s0.params, s1.params)))
    assert err < 1e-5


def test_overflow_skips_update_and_halves_scale(make_engine):
    """Inject an overflow via an absurd scale: params must be unchanged
    and the scale halved."""
    eng = make_engine("l2l-p", dtype="float16", optimizer=adam(1e-3),
                      exec_cfg=ExecutionConfig(n_microbatches=2,
                                               loss_scale_init=2.0 ** 30))
    cfg = eng.model.cfg
    batch = make_batch(cfg, 4, 16, dtype=jnp.float16)
    state = eng.init(jax.random.PRNGKey(0))
    new_state, m = eng.train_step(state, batch)
    assert int(m["nonfinite_layers"]) > 0
    assert float(new_state.loss_scale["scale"]) == 2.0 ** 29
    diff = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state.params["groups"], new_state.params["groups"])))
    assert diff == 0.0, "overflowed layers must skip their update"
