"""Deliverable (f): per-architecture smoke tests.

Each assigned architecture instantiates a REDUCED variant of the same
family (2 layers, d_model<=512, <=4 experts) and runs one forward and one
L2L train step on CPU, asserting output shapes and absence of NaNs."""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.configs.base import get_config, list_archs
from repro.core.schedule import ExecutionConfig
from repro.models.model import LayeredModel
from repro.optim import adam

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_reduced_limits(arch):
    cfg = get_config(arch, "smoke")
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch, "smoke")
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    loss, (loss_sum, wsum, aux) = jax.jit(
        lambda p, b: model.full_loss(p, b))(params, batch)
    assert jnp.isfinite(loss), arch
    assert float(wsum) == B * S


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, make_engine):
    cfg = get_config(arch, "smoke")
    eng = make_engine("l2l-p", arch, dtype=None, optimizer=adam(lr=1e-3),
                      exec_cfg=ExecutionConfig(n_microbatches=2))
    batch = make_batch(cfg, 4, 16)
    state = eng.init(jax.random.PRNGKey(0))
    params = state.params
    new_state, metrics = eng.train_step(state, batch)
    new_params = new_state.params
    assert jnp.isfinite(metrics["loss"]), arch
    assert jnp.isfinite(metrics["grad_norm"]), arch
    assert int(new_state.step) == 1
    # params actually moved, shapes preserved
    moved = jax.tree.map(
        lambda a, b: (a.shape == b.shape
                      and bool(jnp.any(a != b))), params, new_params)
    assert all(jax.tree.leaves(jax.tree.map(
        lambda a, b: a.shape == b.shape, params, new_params)))
    assert any(jax.tree.leaves(moved)), f"{arch}: no parameter changed"
    assert all(jnp.isfinite(l.astype(jnp.float32)).all()
               for l in jax.tree.leaves(new_params)), arch
