"""Constant-memory stash (ExecutionConfig.stash_every) invariants.

With ``stash_every = K`` the forward relay checkpoints only the layer
boundaries at indices = 0 (mod K) within each group — ceil(N/K) stashed
boundaries instead of N — and the reverse relay recomputes the missing
boundaries by re-streaming each K-segment's weights forward through the
relay executor before running the recompute-vjp backward over the
segment.  That is a pure SCHEDULE change: gradients, post-update params
and optimizer state must be bit-identical to the stash-every-boundary
schedule for every (K, G, prefetch, pack) point, for both the trailing
(l2l / Alg 3) and eager (l2l-p / Alg 4) optimizers — including
non-divisible depths (remainder segment), K = N (one checkpoint per
group) and K > N.
"""
import itertools

import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.configs.base import get_config
from repro.core.relay import segment_bounds
from repro.core.schedule import ExecutionConfig
from repro.optim import adam

# n_layers=5 below: K=2 and K=3 leave a short remainder segment
# (non-divisible depth), K=5 == N is the single-checkpoint-per-group
# edge, K=7 > N.  Crossed with {G} x {prefetch} x {pack} so the segment
# recompute is exercised against grouping, the prefetch ring and the
# packed flat-buffer transport — mirroring test_relay.py's grid.
KS = (2, 3, 5, 7)
GRID = list(itertools.product(KS, (1, 3), (0, 2), (False, True)))


def _cfg(arch="bert-large", n_layers=5):
    return get_config(arch, "smoke").replace(dtype="float32",
                                             n_layers=n_layers)


def _assert_trees_bitwise(a, b, what):
    mismatched = [
        k for k, (x, y) in enumerate(zip(jax.tree.leaves(a),
                                         jax.tree.leaves(b)))
        if not bool(jnp.all(x == y))]
    assert not mismatched, f"{what}: leaves {mismatched} differ"


# ---------------------------------------------------------------------------
# segment_bounds unit behavior
# ---------------------------------------------------------------------------
def test_segment_bounds():
    assert segment_bounds(5, 1) == ((0, 1), (1, 2), (2, 3), (3, 4), (4, 5))
    assert segment_bounds(5, 2) == ((0, 2), (2, 4), (4, 5))
    assert segment_bounds(5, 3) == ((0, 3), (3, 5))
    assert segment_bounds(5, 5) == ((0, 5),)
    assert segment_bounds(5, 7) == ((0, 5),)
    assert segment_bounds(6, 2) == ((0, 2), (2, 4), (4, 6))
    for n, k in [(5, 2), (24, 7), (1, 3), (6, 6)]:
        segs = segment_bounds(n, k)
        assert len(segs) == -(-n // k)                 # ceil(N/K)
        assert segs[0][0] == 0 and segs[-1][1] == n
        assert all(a1 == b0 for (_, a1), (b0, _) in zip(segs, segs[1:]))
        assert all(s0 % k == 0 for s0, _ in segs)      # = 0 (mod K)


def test_stash_every_validated():
    assert ExecutionConfig(stash_every=4).stash_every == 4
    with pytest.raises(AssertionError):
        ExecutionConfig(stash_every=0)


def test_registry_threads_stash_every():
    from repro import engine as engines
    eng = engines.create("l2l-p", get_config("bert-large", "smoke"),
                         ExecutionConfig(n_microbatches=2),
                         exec_overrides={"stash_every": 3})
    assert eng.exec_cfg.stash_every == 3


# ---------------------------------------------------------------------------
# full train step: every (K, G, prefetch, pack) point is bit-identical
# to stash_every=1 for l2l and l2l-p
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["l2l", "l2l-p"])
def test_stash_train_step_bit_identical_across_grid(name, make_engine):
    """One optimizer step (trailing Alg-3 relay for l2l, eager Alg-4 for
    l2l-p): grads, post-update params and opt state must match the K=1
    reference bitwise across {K} x {G} x {prefetch} x {pack}."""
    from repro.core import packing
    cfg = _cfg()
    batch = make_batch(cfg, 4, 16)
    ref = None
    for K, G, k, pk in [(1, 1, 0, False)] + GRID:
        eng = make_engine(name, optimizer=adam(lr=1e-3),
                          exec_cfg=ExecutionConfig(
                              n_microbatches=2, stash_every=K,
                              prefetch_depth=k, layers_per_relay=G,
                              pack_params=pk),
                          cfg=cfg)
        state, m = eng.train_step(eng.init(jax.random.PRNGKey(0)), batch)
        params, opt = state.params, state.legacy_opt()
        if pk:
            opt = packing.unpack_opt_state(opt, params)
            params = packing.unpack_params(params)
        if ref is None:
            ref = (float(m["loss"]), params, opt)
            continue
        tag = f"{name} K={K} G={G} k={k} pack={pk}"
        assert float(m["loss"]) == ref[0], tag
        _assert_trees_bitwise(params, ref[1], f"{tag} params")
        _assert_trees_bitwise(opt, ref[2], f"{tag} opt state")


def test_stash_grads_cover_multi_group_and_mem_archs(make_engine):
    """The segment recompute must thread the encoder-decoder transition
    and cross-attention memory (whisper: two groups of different,
    non-divisible depths) exactly like the every-boundary schedule."""
    from repro.models.model import LayeredModel
    cfg = get_config("whisper-base", "smoke").replace(dtype="float32")
    batch = make_batch(cfg, 4, 16)
    params = LayeredModel(cfg).init_params(jax.random.PRNGKey(0))
    outs = {}
    for K, G, k, pk in [(1, 1, 0, False), (2, 2, 1, True),
                        (3, 1, 2, False), (4, 3, 0, False)]:
        eng = make_engine("l2l-p", "whisper-base", exec_cfg=ExecutionConfig(
            n_microbatches=2, stash_every=K, prefetch_depth=k,
            layers_per_relay=G, pack_params=pk))
        outs[(K, G, k, pk)] = eng.grads(params, batch)
    ref = outs[(1, 1, 0, False)]
    for key, (loss, g) in outs.items():
        assert float(loss) == float(ref[0]), f"whisper {key}"
        _assert_trees_bitwise(g, ref[1], f"whisper {key}")


def test_stash_composes_with_amp_loss_scale(make_engine):
    """The recompute backward also carries the AMP head cotangent and the
    per-layer finiteness skip — one scaled step must match K=1 bitwise."""
    cfg = _cfg()
    batch = make_batch(cfg, 4, 16)
    ref = None
    for K in (1, 2, 5):
        eng = make_engine("l2l-p", optimizer=adam(lr=1e-3),
                          exec_cfg=ExecutionConfig(
                              n_microbatches=2, stash_every=K,
                              loss_scale_init=2.0 ** 10),
                          cfg=cfg)
        state, m = eng.train_step(eng.init(jax.random.PRNGKey(0)), batch)
        got = (float(m["loss"]), state.params, state.legacy_opt())
        if ref is None:
            ref = got
            continue
        assert got[0] == ref[0], f"K={K}"
        _assert_trees_bitwise(got[1], ref[1], f"K={K} params")
        _assert_trees_bitwise(got[2], ref[2], f"K={K} opt state")
