"""Packed flat-buffer relay (ExecutionConfig.pack_params) invariants.

Packing coalesces each layer's weight pytree (and optimizer slots) into
contiguous per-dtype flat buffers so the EPS relay issues one large DMA
per layer per direction.  That must be a pure LAYOUT change: pack->unpack
is bit-lossless for every arch, the fused flat-segment optimizer
(kernels/fused_adam_flat) matches the per-leaf optim.adam/adamw exactly,
and pack_params=True computes bit-identical grads, updates, prefill and
decode outputs to pack_params=False for both l2l and l2l-p (mirroring
tests/test_prefetch.py for the relay-depth knob)."""
import functools
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs.base import get_config, list_archs
from repro.core import packing
from repro.core.memory_model import estimate
from repro.core.schedule import ExecutionConfig
from repro.models.model import LayeredModel
from repro.optim import adam, adamw, lamb, sgd


def _cfg(arch="bert-large"):
    return get_config(arch, "smoke").replace(dtype="float32")


def _assert_trees_bitwise(a, b, what):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), f"{what}: leaf count {len(la)} vs {len(lb)}"
    mismatched = [k for k, (x, y) in enumerate(zip(la, lb))
                  if not bool(jnp.all(x == y))]
    assert not mismatched, f"{what}: leaves {mismatched} differ"


# ---------------------------------------------------------------------------
# pack -> unpack roundtrip, every arch of the smoke config set
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", list_archs())
def test_pack_roundtrip_bit_identity(arch):
    cfg = get_config(arch, "smoke")
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    packed = packing.pack_params(params)
    for g in packed["groups"]:
        assert packing.is_packed(g)
        # one buffer per dtype: the relay moves len(segs) arrays per layer
        assert all(b.ndim == 2 for b in g.segs.values())
    restored = packing.unpack_params(packed)
    assert jax.tree.structure(params) == jax.tree.structure(restored)
    _assert_trees_bitwise(params, restored, f"{arch} roundtrip")
    # opt-state roundtrip rides the same specs (slot-major, aligned)
    opt = {"step": jnp.int32(0),
           "embed": adam().init(params["embed"]),
           "head": adam().init(params["head"]),
           "groups": tuple(adam().init(g) for g in params["groups"])}
    opt_packed = packing.pack_opt_state(opt, packed)
    for g in opt_packed["groups"]:
        assert packing.opt_is_packed(g) and sorted(g) == ["m", "v"]
    _assert_trees_bitwise(opt, packing.unpack_opt_state(opt_packed, packed),
                          f"{arch} opt roundtrip")


def test_pack_mixed_dtype_segregation():
    """dtype-segregated segments: mixed trees split into one buffer per
    dtype, with odd (non-power-of-two) leaf sizes preserved exactly."""
    tree = {"a": jnp.arange(3 * 7, dtype=jnp.float32).reshape(3, 7),
            "b": (jnp.arange(3 * 5, dtype=jnp.bfloat16).reshape(3, 5),
                  jnp.arange(3 * 13, dtype=jnp.float32).reshape(3, 13, 1)),
            "c": jnp.ones((3,), jnp.bfloat16)}
    pk = packing.pack(tree)            # stacked: leading axis 3
    assert sorted(pk.segs) == ["bfloat16", "float32"]
    assert pk.segs["float32"].shape == (3, 7 + 13)
    assert pk.segs["bfloat16"].shape == (3, 5 + 1)
    _assert_trees_bitwise(tree, packing.unpack(pk), "mixed roundtrip")
    # slice packing (one layer) through the same spec
    sl = jax.tree.map(lambda a: a[1], tree)
    pk_sl = packing.pack(sl, spec=pk.spec, stacked=False)
    _assert_trees_bitwise(sl, packing.unpack(pk_sl), "slice roundtrip")


# ---------------------------------------------------------------------------
# fused flat optimizer vs per-leaf optim.adam/adamw: bit parity on
# mixed-dtype trees with odd leaf sizes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("make_opt", [
    adam, adamw,
    # adamw at weight_decay=0 must keep adamw's update association
    # (a*(m/d + 0*p)), which differs from adam's (a*m)/d in the last ulp
    functools.partial(adamw, weight_decay=0.0),
])
def test_flat_update_bit_matches_per_leaf(make_opt):
    ks = jax.random.split(jax.random.PRNGKey(3), 8)
    tree = {
        "w": jax.random.normal(ks[0], (37, 11), jnp.float32),
        "scale": jnp.abs(jax.random.normal(ks[1], (129,), jnp.float32)),
        "half": (jax.random.normal(ks[2], (7, 3, 5)) / 8).astype(
            jnp.bfloat16),
    }
    opt = make_opt(lr=3e-3)
    grads = jax.tree.map(
        lambda p, k: jax.random.normal(k, p.shape, jnp.float32),
        tree, jax.tree.unflatten(jax.tree.structure(tree),
                                 list(jax.random.split(ks[3], 3))))
    # two chained steps so the parity covers zero AND warm moments; both
    # sides run under jit — that is how the engines execute them, and
    # XLA's fusion choices (FMA contraction) must agree for bitwise
    # comparison to be meaningful
    spec = packing.build_spec(tree, stacked=False)

    @jax.jit
    def ref_step(p, s, step):
        return opt.update(grads, s, p, step)

    @jax.jit
    def flat_step(p, s, step):
        w_pk = packing.pack(p, spec=spec, stacked=False)
        g_pk = packing.pack(grads, spec=spec, stacked=False)
        s_pk = packing.pack_opt(spec, s, stacked=False)
        new_p, new_m, new_v = {}, {}, {}
        for key in sorted(w_pk.segs):
            p2, m2, v2 = opt.flat_update(
                w_pk.segs[key], g_pk.segs[key],
                s_pk["m"].segs[key], s_pk["v"].segs[key], step)
            new_p[key], new_m[key], new_v[key] = p2, m2, v2
        return (packing.unpack(packing.Packed(new_p, spec)),
                packing.unpack_opt(
                    spec, {"m": packing.Packed(new_m, spec),
                           "v": packing.Packed(new_v, spec)}))

    ref_p, ref_s = tree, opt.init(tree)
    got_p, got_s = tree, opt.init(tree)
    for step in (jnp.int32(0), jnp.int32(1)):
        ref_p, ref_s = ref_step(ref_p, ref_s, step)
        got_p, got_s = flat_step(got_p, got_s, step)
        _assert_trees_bitwise(ref_p, got_p, f"{opt.name} flat params")
        _assert_trees_bitwise(ref_s, got_s, f"{opt.name} flat slots")


def test_flat_update_absent_for_non_adam():
    assert lamb().flat_update is None
    assert sgd().flat_update is None
    assert adam().flat_update is not None
    assert adamw().flat_update is not None


# ---------------------------------------------------------------------------
# packed vs unpacked: bit-identical schedules (mirrors test_prefetch.py)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["l2l", "l2l-p"])
def test_pack_grads_bit_identical(name, make_engine):
    cfg = _cfg()
    batch = make_batch(cfg, 4, 16)
    params = LayeredModel(cfg).init_params(jax.random.PRNGKey(0))
    outs = {}
    for pk in (False, True):
        eng = make_engine(name, exec_cfg=ExecutionConfig(
            n_microbatches=2, pack_params=pk))
        outs[pk] = eng.grads(params, batch)
    assert float(outs[False][0]) == float(outs[True][0])
    _assert_trees_bitwise(outs[False][1], outs[True][1], f"{name} grads")


@pytest.mark.parametrize("name", ["l2l", "l2l-p"])
@pytest.mark.parametrize("make_opt", [adam, lamb])
def test_pack_updates_bit_identical(name, make_opt, make_engine):
    """Full train step: the fused flat-segment optimizer (adam) and the
    unpack->per-leaf->repack fallback (lamb) must both produce new params
    and opt state bitwise equal to the unpacked schedule."""
    cfg = _cfg()
    batch = make_batch(cfg, 4, 16)
    states = {}
    for pk in (False, True):
        eng = make_engine(name, optimizer=make_opt(lr=1e-3),
                          exec_cfg=ExecutionConfig(n_microbatches=2,
                                                   pack_params=pk))
        state, m = eng.train_step(eng.init(jax.random.PRNGKey(0)), batch)
        params, opt = state.params, state.legacy_opt()
        if pk:
            opt = packing.unpack_opt_state(opt, params)
            params = packing.unpack_params(params)
        states[pk] = (params, opt, float(m["loss"]))
    assert states[False][2] == states[True][2]
    _assert_trees_bitwise(states[False][0], states[True][0],
                          f"{name}/{make_opt().name} params")
    _assert_trees_bitwise(states[False][1], states[True][1],
                          f"{name}/{make_opt().name} opt state")


def test_pack_covers_multi_group_and_mem_archs(make_engine):
    """Transition/mem handling (whisper enc-dec) and MoE/MLA layers relay
    through the same packed scans; composed with prefetch_depth=1 the
    double buffer carries the flat segments."""
    for arch in ("whisper-base", "deepseek-v2-lite-16b"):
        cfg = _cfg(arch)
        batch = make_batch(cfg, 4, 16)
        params = LayeredModel(cfg).init_params(jax.random.PRNGKey(0))
        outs = {}
        for pk in (False, True):
            eng = make_engine("l2l-p", arch, exec_cfg=ExecutionConfig(
                n_microbatches=2, prefetch_depth=1, pack_params=pk))
            outs[pk] = eng.grads(params, batch)
        _assert_trees_bitwise(outs[False][1], outs[True][1], arch)


def test_pack_prefill_and_decode_bit_identical(make_engine):
    cfg = _cfg("granite-3-8b")
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    outs = {}
    for pk in (False, True):
        eng = make_engine("l2l", "granite-3-8b", exec_cfg=ExecutionConfig(
            n_microbatches=2, pack_params=pk))
        params = eng.model.init_params(jax.random.PRNGKey(0))
        logits = eng.prefill(params, {"tokens": make_batch(cfg, 4, 16)[
            "tokens"]})
        caches, last = eng.decode_init(params, toks, live_seq=16)
        step_logits, _ = eng.decode_step(
            params, caches, jnp.argmax(last, -1)[:, None].astype(jnp.int32),
            jnp.int32(8))
        outs[pk] = (logits, last, step_logits)
    for a, b in zip(outs[False], outs[True]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# facade boundary: checkpoints stay unpacked; states interchange
# ---------------------------------------------------------------------------
def test_pack_checkpoint_interchange(make_engine):
    cfg = _cfg()
    batch = make_batch(cfg, 4, 16)
    e_pk = make_engine("l2l-p", optimizer=adam(lr=1e-3),
                       exec_cfg=ExecutionConfig(n_microbatches=2,
                                                pack_params=True))
    e_up = make_engine("l2l-p", optimizer=adam(lr=1e-3),
                       exec_cfg=ExecutionConfig(n_microbatches=2))
    state, _ = e_pk.train_step(e_pk.init(jax.random.PRNGKey(0)), batch)
    with tempfile.TemporaryDirectory() as d:
        e_pk.save(d, state, step=1)
        st_up, step_up = e_up.restore(d)       # packed ckpt -> unpacked run
        st_pk, step_pk = e_pk.restore(d)       # ... -> packed run
    assert step_up == step_pk == 1
    _assert_trees_bitwise(packing.unpack_params(state.params),
                          st_up.params, "ckpt params (unpacked view)")
    _assert_trees_bitwise(state.params, st_pk.params,
                          "ckpt params (packed view)")
    _assert_trees_bitwise(state.opt_state, st_pk.opt_state,
                          "ckpt opt state (packed view)")


def test_baseline_engine_ignores_pack(make_engine):
    eng = make_engine("baseline", exec_cfg=ExecutionConfig(
        n_microbatches=2, pack_params=True))
    assert not eng.exec_cfg.pack_params
    state = eng.init(jax.random.PRNGKey(0))
    assert not any(packing.is_packed(g) for g in state.params["groups"])


# ---------------------------------------------------------------------------
# memory model: packed transit changes the DMA issue counts, not bytes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["l2l", "l2l_p"])
def test_memory_estimate_packed_transit_counts(mode):
    model = LayeredModel(get_config("bert-large"))
    r0 = estimate(model, batch=32, seq=512, n_microbatches=8, mode=mode,
                  offload_stash=True)
    r1 = estimate(model, batch=32, seq=512, n_microbatches=8, mode=mode,
                  offload_stash=True, pack_params=True)
    # bytes are layout-independent ...
    assert r1.total_device == r0.total_device
    assert r1.total_host == r0.total_host
    # ... the DMA issue count per relayed layer is what collapses
    assert r0.relay_copies_weights > 1
    assert r1.relay_copies_weights == 1
    if mode == "l2l_p":
        assert r0.relay_copies_opt == 2 * r0.relay_copies_weights
        assert r1.relay_copies_opt == 2   # one copy per (m, v) slot
    else:
        assert r0.relay_copies_opt == r1.relay_copies_opt == 0


def test_engine_memory_estimate_threads_pack(make_engine):
    e0 = make_engine("l2l-p", exec_cfg=ExecutionConfig(n_microbatches=2))
    e1 = make_engine("l2l-p", exec_cfg=ExecutionConfig(n_microbatches=2,
                                                       pack_params=True))
    r0 = e0.memory_estimate(batch=8, seq=64)
    r1 = e1.memory_estimate(batch=8, seq=64)
    assert r0.relay_copies_weights > 1 and r1.relay_copies_weights == 1
    assert r1.total_device == r0.total_device


# ---------------------------------------------------------------------------
# satellite regression pin: embedding lookup is unscaled (the historical
# `x * (1.0 if rmsnorm else 1.0)` dead expression is gone)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("norm_type", ["rmsnorm", "layernorm"])
def test_embed_tokens_unscaled(norm_type):
    from repro.models.common import embed_tokens
    cfg = get_config("bert-large", "smoke").replace(norm_type=norm_type)
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    dt = jnp.dtype(cfg.dtype)
    got = embed_tokens(params["embed"], toks, cfg, dt)
    raw = jnp.take(params["embed"]["tok"], toks, axis=0).astype(dt)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(raw))
    # train/prefill (prepare) and decode (decode_embed) agree on the same
    # unscaled rows
    static = {"embed": params["embed"], "head": params["head"]}
    x_train, _ = model.prepare(static, {"tokens": toks})
    np.testing.assert_array_equal(np.asarray(x_train), np.asarray(raw))
    x_dec = model.decode_embed(static, toks[:, :1], jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(x_dec),
                                  np.asarray(raw[:, :1]))
