"""Unified relay executor (repro.core.relay) invariants.

The relay executor composes weight streaming, the k-deep prefetch ring
(prefetch_depth), packed flat-buffer transport (pack_params) and G-layer
relay groups (layers_per_relay) exactly once, for every consumer scan
(train forward, reverse backward, trailing update, prefill, decode).
That composition must be a pure SCHEDULE/layout change: every (G, k,
pack) point computes bit-identical grads, updates, prefill logits and
decode steps to the plain per-layer scan — including depths NOT
divisible by G (remainder stop) and G > N (remainder-only, no main
scan).
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs.base import get_config
from repro.core import relay
from repro.core.eps import noop_placement
from repro.core.schedule import ExecutionConfig
from repro.optim import adam

# {G} x {prefetch_depth} x {pack on/off}; n_layers=5 below makes G=2, 3
# leave a remainder stop and G=7 a remainder-only pass
GRID = list(itertools.product((1, 2, 3), (0, 1, 2), (False, True)))
EDGE = [(5, 1, False), (7, 2, True)]   # G == N and G > N


def _cfg(arch="bert-large", n_layers=5):
    return get_config(arch, "smoke").replace(dtype="float32",
                                             n_layers=n_layers)


def _assert_trees_bitwise(a, b, what):
    mismatched = [
        k for k, (x, y) in enumerate(zip(jax.tree.leaves(a),
                                         jax.tree.leaves(b)))
        if not bool(jnp.all(x == y))]
    assert not mismatched, f"{what}: leaves {mismatched} differ"


# ---------------------------------------------------------------------------
# relay_scan unit behavior (no engine): order, ys stacking, remainder
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("group,prefetch,reverse", [
    (1, 0, False), (1, 2, True), (2, 0, False), (2, 1, True),
    (3, 2, False), (3, 1, True), (7, 1, False), (5, 0, True)])
def test_relay_scan_visits_layers_in_order(group, prefetch, reverse):
    """Bodies run per layer, in direction order, and ys keep layer order
    regardless of grouping/prefetch/remainder handling."""
    n = 5
    stacked = {"w": jnp.arange(n, dtype=jnp.float32) + 1.0}
    xs = jnp.arange(n, dtype=jnp.float32) * 10.0

    def body(carry, slots, x):
        (slot,) = slots
        return carry + slot["w"], slot["w"] * 100.0 + x

    stream = relay.Stream(noop_placement(), stacked)
    total, ys = jax.jit(lambda: relay.relay_scan(
        body, jnp.float32(0.0), (stream,), xs=xs,
        reverse=reverse, group=group, prefetch=prefetch))()
    assert float(total) == sum(range(1, n + 1))
    np.testing.assert_array_equal(
        np.asarray(ys), (np.arange(n) + 1.0) * 100.0 + np.arange(n) * 10.0)


def test_relay_scan_reverse_carry_order():
    """A reverse relay must thread the carry from layer N-1 down to 0
    (order-sensitive carry), with any grouping."""
    n = 5
    stacked = jnp.arange(n, dtype=jnp.float32) + 1.0

    def body(carry, slots, x):
        return carry * 10.0 + slots[0], None

    ref = None
    for g, k in [(1, 0), (2, 1), (3, 2), (2, 2)]:
        out, _ = jax.jit(lambda g=g, k=k: relay.relay_scan(
            body, jnp.float32(0.0),
            (relay.Stream(noop_placement(), stacked),),
            reverse=True, group=g, prefetch=k))()
        ref = out if ref is None else ref
        assert float(out) == float(ref) == 54321.0


def test_n_stops():
    assert relay.n_stops(24, 1) == 24
    assert relay.n_stops(24, 4) == 6
    assert relay.n_stops(5, 2) == 3
    assert relay.n_stops(5, 3) == 2
    assert relay.n_stops(2, 7) == 1


# ---------------------------------------------------------------------------
# full train step: the (G, k, pack) grid is bit-identical for l2l + l2l-p
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["l2l", "l2l-p"])
def test_relay_train_step_bit_identical_across_grid(name, make_engine):
    """One optimizer step (trailing Alg-3 relay for l2l, eager Alg-4 for
    l2l-p) across the full {G} x {prefetch} x {pack} grid, n_layers=5 so
    G=2/3 exercise the remainder stop."""
    from repro.core import packing
    cfg = _cfg()
    batch = make_batch(cfg, 4, 16)
    ref = None
    for G, k, pk in GRID + EDGE:
        eng = make_engine(name, optimizer=adam(lr=1e-3),
                          exec_cfg=ExecutionConfig(
                              n_microbatches=2, prefetch_depth=k,
                              layers_per_relay=G, pack_params=pk),
                          cfg=cfg)
        state, m = eng.train_step(eng.init(jax.random.PRNGKey(0)), batch)
        params, opt = state.params, state.legacy_opt()
        if pk:
            opt = packing.unpack_opt_state(opt, params)
            params = packing.unpack_params(params)
        if ref is None:
            ref = (float(m["loss"]), params, opt)
            continue
        tag = f"{name} G={G} k={k} pack={pk}"
        assert float(m["loss"]) == ref[0], tag
        _assert_trees_bitwise(params, ref[1], f"{tag} params")
        _assert_trees_bitwise(opt, ref[2], f"{tag} opt state")


def test_relay_grads_cover_multi_group_and_mem_archs(make_engine):
    """Transition/mem handling (whisper enc-dec: two groups of different
    depth) and MoE/MLA layers go through the same grouped/ringed scans."""
    from repro.models.model import LayeredModel
    for arch in ("whisper-base", "deepseek-v2-lite-16b"):
        cfg = get_config(arch, "smoke").replace(dtype="float32")
        batch = make_batch(cfg, 4, 16)
        params = LayeredModel(cfg).init_params(jax.random.PRNGKey(0))
        outs = {}
        for G, k, pk in [(1, 0, False), (2, 2, True), (3, 1, False)]:
            eng = make_engine("l2l-p", arch, exec_cfg=ExecutionConfig(
                n_microbatches=2, prefetch_depth=k, layers_per_relay=G,
                pack_params=pk))
            outs[(G, k, pk)] = eng.grads(params, batch)
        ref = outs[(1, 0, False)]
        for key, (loss, g) in outs.items():
            assert float(loss) == float(ref[0]), f"{arch} {key}"
            _assert_trees_bitwise(g, ref[1], f"{arch} {key}")


def test_relay_prefill_and_decode_bit_identical(make_engine):
    cfg = _cfg("granite-3-8b")
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    outs = {}
    combos = [(1, 0, False), (2, 1, False), (3, 2, True), (2, 2, True)]
    for G, k, pk in combos:
        eng = make_engine("l2l", "granite-3-8b", exec_cfg=ExecutionConfig(
            n_microbatches=2, prefetch_depth=k, layers_per_relay=G,
            pack_params=pk), cfg=cfg)
        params = eng.model.init_params(jax.random.PRNGKey(0))
        logits = eng.prefill(params, {"tokens": make_batch(cfg, 4, 16)[
            "tokens"]})
        caches, last = eng.decode_init(params, toks, live_seq=16)
        step_logits, _ = eng.decode_step(
            params, caches, jnp.argmax(last, -1)[:, None].astype(jnp.int32),
            jnp.int32(8))
        outs[(G, k, pk)] = (logits, last, step_logits)
    for key in combos[1:]:
        for a, b in zip(outs[combos[0]], outs[key]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{key}")


# ---------------------------------------------------------------------------
# config validation + knob threading
# ---------------------------------------------------------------------------
def test_relay_knobs_validated():
    assert ExecutionConfig(prefetch_depth=2).prefetch_depth == 2
    assert ExecutionConfig(layers_per_relay=4).layers_per_relay == 4
    with pytest.raises(AssertionError):
        ExecutionConfig(prefetch_depth=-1)
    with pytest.raises(AssertionError):
        ExecutionConfig(layers_per_relay=0)


def test_registry_threads_group():
    from repro import engine as engines
    eng = engines.create("l2l-p", get_config("bert-large", "smoke"),
                         ExecutionConfig(n_microbatches=4),
                         exec_overrides={"layers_per_relay": 3,
                                         "prefetch_depth": 2})
    assert eng.exec_cfg.layers_per_relay == 3
    assert eng.exec_cfg.prefetch_depth == 2
