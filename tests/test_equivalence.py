"""The paper's core correctness claim: L2L execution computes the SAME
gradients/updates as baseline-with-accumulated-gradients (Alg 2 == Alg 3
== Alg 4 numerically), which is why Fig 3/4's learning curves coincide."""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.configs.base import get_config, list_archs
from repro.core import baseline, l2l
from repro.core.schedule import ExecutionConfig
from repro.models.model import LayeredModel
from repro.optim import adam

ARCHS = list_archs()


def _rel_err(a, b):
    num = max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(
            x.astype(jnp.float32) - y.astype(jnp.float32)))), a, b)))
    den = max(max(float(jnp.max(jnp.abs(x.astype(jnp.float32))))
                  for x in jax.tree.leaves(a)), 1e-12)
    return num / den


@pytest.mark.parametrize("arch", ARCHS)
def test_l2l_grads_match_baseline_ag(arch):
    cfg = get_config(arch, "smoke").replace(dtype="float32")
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 16)
    ec = ExecutionConfig(n_microbatches=2)
    l_base, g_base = jax.jit(baseline.make_grads_fn(model, ec))(params, batch)
    l_l2l, g_l2l = jax.jit(l2l.make_grads_fn(model, ec))(params, batch)
    assert abs(float(l_base) - float(l_l2l)) < 1e-4
    assert _rel_err(g_base, g_l2l) < 1e-4, arch


@pytest.mark.parametrize("ub", [1, 2, 4])
def test_microbatch_count_invariance(ub):
    """Alg 3's point: more microbatches never changes the math."""
    cfg = get_config("bert-large", "smoke").replace(dtype="float32")
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 16)
    _, g1 = jax.jit(l2l.make_grads_fn(
        model, ExecutionConfig(n_microbatches=1)))(params, batch)
    _, gu = jax.jit(l2l.make_grads_fn(
        model, ExecutionConfig(n_microbatches=ub)))(params, batch)
    assert _rel_err(g1, gu) < 1e-4


def test_alg3_equals_alg4_updates():
    """Eager (L2L-p) and trailing (L2L) optimizer orders produce identical
    updated parameters."""
    cfg = get_config("granite-3-8b", "smoke").replace(dtype="float32")
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 16)
    opt = adam(lr=1e-3)
    p3 = None
    outs = {}
    for eager in (False, True):
        step = jax.jit(l2l.make_train_step(
            model, opt, ExecutionConfig(n_microbatches=2,
                                        eager_optimizer=eager)))
        st = l2l.init_opt_state(opt, params)
        new_p, new_o, m = step(params, st, batch)
        outs[eager] = (new_p, m)
    err = _rel_err(outs[False][0], outs[True][0])
    assert err < 1e-5, err
    assert abs(float(outs[False][1]["loss"]) -
               float(outs[True][1]["loss"])) < 1e-5


def test_l2l_step_equals_baseline_step():
    """Full train step (grads + adam) parity: L2L-p vs Algorithm 2."""
    cfg = get_config("chatglm3-6b", "smoke").replace(dtype="float32")
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    batch = make_batch(cfg, 4, 16, seed=3)
    opt = adam(lr=1e-3)
    ec = ExecutionConfig(n_microbatches=2)
    s_l2l = jax.jit(l2l.make_train_step(model, opt, ec))
    s_base = jax.jit(baseline.make_train_step(model, opt, ec))
    p1, o1, m1 = s_l2l(params, l2l.init_opt_state(opt, params), batch)
    p2, o2, m2 = s_base(params, baseline.init_opt_state(opt, params), batch)
    assert _rel_err(p1, p2) < 1e-5
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5


def test_per_layer_clip_consistency():
    cfg = get_config("bert-large", "smoke").replace(dtype="float32")
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 16)
    opt = adam(lr=1e-2)
    ec = ExecutionConfig(n_microbatches=2, clip_mode="per_layer",
                         clip_norm=1e-3)
    step = jax.jit(l2l.make_train_step(model, opt, ec))
    p, o, m = step(params, l2l.init_opt_state(opt, params), batch)
    assert jnp.isfinite(m["loss"])
    # with a tiny clip norm the layer updates are bounded by ~lr
    deltas = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                          params["groups"], p["groups"])
    assert max(jax.tree.leaves(deltas)) < 0.1
