"""The paper's core correctness claim: L2L execution computes the SAME
gradients/updates as baseline-with-accumulated-gradients (Alg 2 == Alg 3
== Alg 4 numerically), which is why Fig 3/4's learning curves coincide.
All schedules are driven through the public Engine facade."""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.configs.base import get_config, list_archs
from repro.core.schedule import ExecutionConfig
from repro.optim import adam

ARCHS = list_archs()


def _rel_err(a, b):
    num = max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(
            x.astype(jnp.float32) - y.astype(jnp.float32)))), a, b)))
    den = max(max(float(jnp.max(jnp.abs(x.astype(jnp.float32))))
                  for x in jax.tree.leaves(a)), 1e-12)
    return num / den


@pytest.mark.parametrize("arch", ARCHS)
def test_l2l_grads_match_baseline_ag(arch, make_engine):
    cfg = get_config(arch, "smoke").replace(dtype="float32")
    batch = make_batch(cfg, 4, 16)
    e_base = make_engine("baseline", arch)
    e_l2l = make_engine("l2l", arch)
    params = e_base.model.init_params(jax.random.PRNGKey(0))
    l_base, g_base = e_base.grads(params, batch)
    l_l2l, g_l2l = e_l2l.grads(params, batch)
    assert abs(float(l_base) - float(l_l2l)) < 1e-4
    assert _rel_err(g_base, g_l2l) < 1e-4, arch


@pytest.mark.parametrize("ub", [1, 2, 4])
def test_microbatch_count_invariance(ub, make_engine):
    """Alg 3's point: more microbatches never changes the math."""
    cfg = get_config("bert-large", "smoke").replace(dtype="float32")
    batch = make_batch(cfg, 4, 16)
    e1 = make_engine("l2l", exec_cfg=ExecutionConfig(n_microbatches=1))
    eu = make_engine("l2l", exec_cfg=ExecutionConfig(n_microbatches=ub))
    params = e1.model.init_params(jax.random.PRNGKey(0))
    _, g1 = e1.grads(params, batch)
    _, gu = eu.grads(params, batch)
    assert _rel_err(g1, gu) < 1e-4


def test_alg3_equals_alg4_updates(make_engine):
    """Eager (L2L-p) and trailing (L2L) optimizer orders produce identical
    updated parameters."""
    cfg = get_config("granite-3-8b", "smoke").replace(dtype="float32")
    batch = make_batch(cfg, 4, 16)
    opt = adam(lr=1e-3)
    outs = {}
    for name in ("l2l", "l2l-p"):
        eng = make_engine(name, "granite-3-8b", optimizer=opt)
        state = eng.init(jax.random.PRNGKey(0))
        new_state, m = eng.train_step(state, batch)
        outs[name] = (new_state.params, m)
    err = _rel_err(outs["l2l"][0], outs["l2l-p"][0])
    assert err < 1e-5, err
    assert abs(float(outs["l2l"][1]["loss"]) -
               float(outs["l2l-p"][1]["loss"])) < 1e-5


def test_l2l_step_equals_baseline_step(make_engine):
    """Full train step (grads + adam) parity: L2L-p vs Algorithm 2."""
    cfg = get_config("chatglm3-6b", "smoke").replace(dtype="float32")
    batch = make_batch(cfg, 4, 16, seed=3)
    opt = adam(lr=1e-3)
    e_l2l = make_engine("l2l-p", "chatglm3-6b", optimizer=opt)
    e_base = make_engine("baseline", "chatglm3-6b", optimizer=opt)
    s1, m1 = e_l2l.train_step(e_l2l.init(jax.random.PRNGKey(1)), batch)
    s2, m2 = e_base.train_step(e_base.init(jax.random.PRNGKey(1)), batch)
    assert _rel_err(s1.params, s2.params) < 1e-5
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    assert int(s1.step) == int(s2.step) == 1


def test_per_layer_clip_consistency(make_engine):
    cfg = get_config("bert-large", "smoke").replace(dtype="float32")
    batch = make_batch(cfg, 4, 16)
    eng = make_engine(
        "l2l-p", optimizer=adam(lr=1e-2),
        exec_cfg=ExecutionConfig(n_microbatches=2, clip_mode="per_layer",
                                 clip_norm=1e-3))
    state = eng.init(jax.random.PRNGKey(0))
    new_state, m = eng.train_step(state, batch)
    assert jnp.isfinite(m["loss"])
    # with a tiny clip norm the layer updates are bounded by ~lr
    deltas = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                          state.params["groups"], new_state.params["groups"])
    assert max(jax.tree.leaves(deltas)) < 0.1
