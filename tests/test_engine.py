"""Engine facade API tests: registry behaviour, cross-engine gradient
parity on a tiny dense model, TrainState round-trips, and the lifecycle
surface (init / train_step / prefill / decode / memory_estimate)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro import engine as engines
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig
from repro.engine import TrainState
from repro.optim import adam


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_lists_all_schedules():
    names = engines.available()
    assert {"baseline", "l2l", "l2l-p"} <= set(names)


def test_registry_unknown_name_raises_with_available_names():
    with pytest.raises(ValueError) as ei:
        engines.create("no-such-engine", get_config("bert-large", "smoke"))
    msg = str(ei.value)
    assert "no-such-engine" in msg
    for name in ("baseline", "l2l", "l2l-p"):
        assert name in msg


def test_registry_is_open_for_extension():
    @engines.register("test-alias-l2lp")
    class AliasEngine(engines.L2LPEngine):
        name = "test-alias-l2lp"

    try:
        assert "test-alias-l2lp" in engines.available()
        eng = engines.create("test-alias-l2lp",
                             get_config("bert-large", "smoke"))
        assert eng.name == "test-alias-l2lp"
        assert eng.exec_cfg.eager_optimizer
    finally:
        engines.registry._REGISTRY.pop("test-alias-l2lp", None)


# ---------------------------------------------------------------------------
# parity: every registered engine computes identical grads on a tiny
# dense model (the paper's Alg 2 == Alg 3 == Alg 4 identity)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", engines.available())
def test_engine_parity_grads(name, make_engine):
    cfg = get_config("bert-large", "smoke").replace(dtype="float32")
    batch = make_batch(cfg, 4, 16)
    ref = make_engine("baseline")
    eng = make_engine(name)
    params = ref.model.init_params(jax.random.PRNGKey(0))
    l_ref, g_ref = ref.grads(params, batch)
    l, g = eng.grads(params, batch)
    assert abs(float(l_ref) - float(l)) < 1e-4, name
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g_ref, g)))
    assert err < 1e-4, (name, err)


@pytest.mark.parametrize("name", engines.available())
def test_engine_lifecycle_train_step(name, make_engine):
    cfg = get_config("bert-large", "smoke").replace(dtype="float32")
    batch = make_batch(cfg, 4, 16)
    eng = make_engine(name, optimizer=adam(lr=1e-3))
    state = eng.init(jax.random.PRNGKey(0))
    assert int(state.step) == 0
    new_state, metrics = eng.train_step(state, batch)
    assert isinstance(new_state, TrainState)
    assert int(new_state.step) == 1
    assert jnp.isfinite(metrics["loss"])
    moved = jax.tree.map(lambda a, b: bool(jnp.any(a != b)),
                         state.params, new_state.params)
    assert any(jax.tree.leaves(moved)), name


# ---------------------------------------------------------------------------
# TrainState
# ---------------------------------------------------------------------------
def test_train_state_legacy_roundtrip(make_engine):
    eng = make_engine("l2l-p")
    state = eng.init(jax.random.PRNGKey(0))
    back = TrainState.from_legacy(state.params, state.legacy_opt())
    assert jax.tree.structure(back) == jax.tree.structure(state)
    assert back.loss_scale is None
    assert set(state.opt_state) == {"embed", "head", "groups"}


def test_train_state_is_jit_transparent(make_engine):
    eng = make_engine("baseline")
    state = eng.init(jax.random.PRNGKey(0))

    @jax.jit
    def bump(s):
        return s.replace(step=s.step + 1)

    assert int(bump(state).step) == 1


def test_engine_save_restore_roundtrip(tmp_path, make_engine):
    eng = make_engine("l2l-p")
    state = eng.init(jax.random.PRNGKey(0))
    eng.save(str(tmp_path), state, step=7)
    restored, step = eng.restore(str(tmp_path))
    assert step == 7
    assert jax.tree.all(jax.tree.map(
        lambda a, b: bool(jnp.all(jnp.asarray(a) == jnp.asarray(b))),
        state.params, restored.params))


# ---------------------------------------------------------------------------
# inference + analysis surface
# ---------------------------------------------------------------------------
def test_engine_prefill_and_decode(make_engine):
    eng = make_engine("l2l", "granite-3-8b", dtype=None,
                      exec_cfg=ExecutionConfig())
    cfg = eng.model.cfg
    params = eng.model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    caches, logits = eng.decode_init(params, toks, live_seq=16)
    assert logits.shape == (2, cfg.vocab_size)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, caches = eng.decode_step(params, caches, tok, jnp.int32(8))
    assert logits2.shape[-1] == cfg.vocab_size

    batch = make_batch(cfg, 4, 16)
    out = eng.prefill(params, {"tokens": batch["tokens"]})
    assert out.shape == (4, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))


def test_engine_memory_estimate_modes(make_engine):
    reports = {}
    for name in engines.available():
        eng = make_engine(name, exec_cfg=ExecutionConfig(
            n_microbatches=8, offload_stash=(name == "l2l-p")))
        reports[name] = eng.memory_estimate(batch=32, seq=128)
    # the L2L device footprint must undercut the baseline's
    base = reports["baseline"].total_device + reports["baseline"].opt_state
    assert reports["l2l"].total_device < base
    assert reports["l2l-p"].total_device < base
    # l2l-p offloads the stash to the EPS host
    assert reports["l2l-p"].stash_on_host


def test_exec_cfg_normalized_per_engine(make_engine):
    ec = ExecutionConfig(n_microbatches=2, eager_optimizer=True)
    assert make_engine("l2l", exec_cfg=ec).exec_cfg.eager_optimizer is False
    ec2 = ExecutionConfig(n_microbatches=2, eager_optimizer=False)
    assert make_engine("l2l-p",
                       exec_cfg=ec2).exec_cfg.eager_optimizer is True


def test_grads_accepts_state_or_params(make_engine):
    cfg = get_config("bert-large", "smoke").replace(dtype="float32")
    batch = make_batch(cfg, 4, 16)
    eng = make_engine("l2l")
    state = eng.init(jax.random.PRNGKey(0))
    l1, g1 = eng.grads(state, batch)
    l2, g2 = eng.grads(state.params, batch)
    assert float(l1) == float(l2)
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(g1)[0]), np.asarray(jax.tree.leaves(g2)[0]))
