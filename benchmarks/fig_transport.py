"""Relay transport A/B: ExecutionConfig.transport x prefetch_depth.

``transport="pallas"`` replaces the relay's scan-boundary ``device_put``
slot moves with the ``kernels/relay_copy`` double-buffered
``make_async_copy`` pipeline, so copy/compute overlap is enforced by the
kernel's DMA semaphores instead of left to XLA's scheduler.  This
benchmark times the l2l-p train step over transport x prefetch_depth
and writes ``BENCH_transport.json`` at the repo root.

What each axis means by backend:

* CPU (this container / CI): the pallas arm runs the copy kernel in
  interpret mode and placements are logical no-ops
  (``eps.memories_supported``), so the A/B bounds the pure
  kernel-dispatch overhead — gated: the pallas arm must stay within 10%
  (geomean) of the xla arm, since the math is bit-identical
  (tests/test_transport.py).
* TPU: the pallas combos pin the stream-in of stop i+1 behind explicit
  DMA semaphores while stop i computes; the ``overlap`` column below is
  the fraction of the measured copy cost that prefetch actually hid —
  the paper's eq. 5-7 overlap term, measured rather than assumed.

``copy_s_per_step`` is probed by timing a fetch-only relay sweep (same
slot mover, no layer compute), so
``overlap = (t[pf=0] - t[pf]) / copy_s`` is well-defined per transport.

Usage::

    PYTHONPATH=src python benchmarks/fig_transport.py --tiny
    PYTHONPATH=src python -m benchmarks.fig_transport --steps 10
"""
import argparse
import itertools
import json
import os
import sys
import time

if __package__ in (None, ""):                       # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import jax
import jax.numpy as jnp

from benchmarks import gate
from benchmarks.common import lm_batch, time_train_step
from repro import engine as engines
from repro.configs.base import get_config
from repro.core.eps import memories_supported
from repro.core.schedule import ExecutionConfig
from repro.optim import adam

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_transport.json")

TRANSPORTS = ("xla", "pallas")
PREFETCH = (0, 1, 2)

# CI gate: the pallas arm must stay within 10% of the xla arm (geomean
# across prefetch depths).  On CPU both arms compute the identical
# program modulo the slot mover, so this bounds the interpret-mode
# kernel dispatch overhead; a real pallas-path regression moves every
# prefetch point at once.
GATE = 1.10


def time_copy_only(cfg, *, transport, iters=20):
    """Fetch-only relay sweep: move every layer slot with the transport's
    slot mover and reduce one element per stop so nothing is dead code.
    The resulting s/step is the serial copy cost the prefetch ring has
    available to hide."""
    from repro.models.model import LayeredModel
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    stacked = params["groups"][0]
    n = jax.tree.leaves(stacked)[0].shape[0]

    if transport == "pallas":
        from repro.kernels import relay_copy

        def fetch(i):
            return relay_copy.fetch_slot(stacked, i, 1)
    else:
        def fetch(i):
            return jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, i, 1), stacked)

    @jax.jit
    def sweep():
        def body(acc, i):
            slot = fetch(i)
            return acc + jax.tree.leaves(slot)[0].ravel()[0], None
        acc, _ = jax.lax.scan(body, jnp.float32(0.0),
                              jnp.arange(n, dtype=jnp.int32))
        return acc

    jax.block_until_ready(sweep())                   # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = sweep()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def time_combo(cfg, batch, *, ub, transport, prefetch, iters, rounds=5):
    eng = engines.create(
        "l2l-p", cfg,
        ExecutionConfig(n_microbatches=ub, weight_stream=True,
                        offload_stash=True, prefetch_depth=prefetch,
                        pack_params=True, transport=transport),
        optimizer=adam(lr=1e-4), donate=False)
    best, compile_s, loss = time_train_step(eng, batch, iters=iters,
                                            rounds=rounds)
    return {"transport": transport, "prefetch_depth": prefetch,
            "s_per_step": best,
            "steps_per_s": 1.0 / max(best, 1e-12),
            "compile_s": round(compile_s, 3),
            "loss": loss}


def run(quick=False, *, arch="bert-large", steps=None, batch=None,
        seq=None, ub=None, out_path=DEFAULT_OUT):
    iters = steps or (5 if quick else 8)
    B = batch or (8 if quick else 16)
    S = seq or (64 if quick else 128)
    UB = ub or (4 if quick else 8)
    cfg = get_config(arch, "smoke")
    data = lm_batch(cfg, B, S)
    prefetches = PREFETCH[:2] if quick else PREFETCH

    results = [time_combo(cfg, data, ub=UB, transport=tr, prefetch=pf,
                          iters=iters)
               for tr, pf in itertools.product(TRANSPORTS, prefetches)]
    copy_s = {tr: time_copy_only(cfg, transport=tr) for tr in TRANSPORTS}

    def step_s(tr, pf):
        return gate.rate_lookup(results, key="s_per_step", transport=tr,
                                prefetch_depth=pf)

    # achieved copy/compute overlap: the fraction of the measured serial
    # copy cost that the prefetch ring hid at each depth.  ~0 on CPU
    # (interpret mode is synchronous); the TPU DMA win lands here.
    overlap = {
        f"{tr}_pf{pf}": max(0.0, min(1.0, (step_s(tr, 0) - step_s(tr, pf))
                                     / max(copy_s[tr], 1e-12)))
        for tr, pf in itertools.product(TRANSPORTS, prefetches[1:])}
    # pallas-vs-xla slowdown at each prefetch depth — the CI gate
    slowdown = {f"pf{pf}": step_s("pallas", pf) / step_s("xla", pf)
                for pf in prefetches}
    record = {
        "benchmark": "fig_transport_relay",
        "backend": jax.default_backend(),
        "memories_supported": memories_supported(),
        "arch": arch, "variant": "smoke",
        "batch": B, "seq": S, "n_microbatches": UB, "timed_steps": iters,
        "results": results,
        "copy_s_per_sweep": copy_s,
        "overlap_achieved": overlap,
        "slowdown_pallas_vs_xla": slowdown,
        "slowdown_geomean": gate.geomean(slowdown.values()),
        "gate": GATE,
        "notes": (
            "l2l-p train step, transport x prefetch_depth.  "
            "copy_s_per_sweep is a fetch-only relay sweep with the same "
            "slot mover; overlap_achieved = (t[pf=0] - t[pf]) / copy_s, "
            "clamped to [0, 1].  On CPU the pallas arm runs in "
            "interpret mode (synchronous), so overlap ~0 and the gate "
            "bounds kernel-dispatch overhead; on TPU the kernel's DMA "
            "semaphores guarantee the stream-in of stop i+1 overlaps "
            "stop i's compute regardless of XLA's scheduler."),
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)

    print("\n# Relay transport A/B (l2l-p train step)")
    print("transport,prefetch,s_per_step,steps_per_s,compile_s")
    for r in results:
        print(f"{r['transport']},{r['prefetch_depth']},"
              f"{r['s_per_step']:.4f},{r['steps_per_s']:.2f},"
              f"{r['compile_s']}")
    for tr in TRANSPORTS:
        print(f"# copy-only sweep ({tr}): {copy_s[tr] * 1e3:.3f}ms")
    for k, v in sorted(overlap.items()):
        print(f"# overlap achieved ({k}): {v:.3f}")
    for k, v in sorted(slowdown.items()):
        print(f"# pallas/xla s_per_step ({k}): {v:.3f}")
    if not memories_supported():
        print("# NOTE: backend drops memory-space transfers — the "
              "semaphore-pinned overlap is a TPU observable; CPU bounds "
              "interpret-mode dispatch overhead only")
    print(f"# wrote {out_path}")
    gate.ceiling_gate(slowdown, GATE, what="pallas/xla slowdown",
                      failure="pallas transport regression: geomean "
                              "pallas-vs-xla slowdown")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="smoke shapes + 5 timed steps x5 rounds (CI)")
    ap.add_argument("--arch", default="bert-large")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ub", type=int, default=None)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    return run(quick=args.tiny, arch=args.arch, steps=args.steps,
               batch=args.batch, seq=args.seq, ub=args.ub,
               out_path=args.out)


if __name__ == "__main__":
    main()
