"""Shared regression-gate helpers for the fig_* benchmarks.

Every fig that backs a CI gate reduces a dict of paired throughput
ratios to a geometric mean (per-combo ratios carry ~5pp of paired
measurement noise on shared runners; a REAL regression moves every
combo at once) and then fails the run one of two ways:

* ``floor_gate`` — a speedup geomean must stay ABOVE a floor; raises
  ``RuntimeError`` so ``benchmarks/run.py``'s collect-and-continue
  harness records the failure and keeps going (fig_pack idiom).
* ``ceiling_gate`` — a slowdown geomean must stay BELOW a ceiling;
  raises ``SystemExit`` (fig_tier idiom).
* ``scaling_gate`` — a rate must grow along a sweep axis: no >10%
  step-to-step drop and a minimum top-vs-first ratio (fig_serve idiom).

``geomean`` is the plain left-fold product (bit-identical to the
``np.prod`` the figs used before the factor-out), and ``rate_lookup``
replaces the per-fig ``next(...)`` result filters.
"""
from __future__ import annotations


def geomean(values) -> float:
    """Left-fold geometric mean of an iterable of ratios."""
    vals = [float(v) for v in values]
    assert vals, "geomean of nothing"
    g = 1.0
    for v in vals:
        g *= v
    return g ** (1.0 / len(vals))


def rate_lookup(results, key="steps_per_s", **match):
    """First ``result[key]`` whose row matches every ``field=value``."""
    return next(r[key] for r in results
                if all(r[f] == v for f, v in match.items()))


def floor_gate(ratios: dict, floor: float, *, what: str,
               failure: str) -> float:
    """Speedup-geomean floor: print the verdict line, raise
    ``RuntimeError`` (collect-and-continue in benchmarks/run.py) when
    the geomean drops below ``floor``.  Returns the geomean."""
    g = geomean(ratios.values())
    status = "ok" if g >= floor else "REGRESSION"
    print(f"# {what} geomean: {g:.3f} [{status}]")
    if g < floor:
        raise RuntimeError(
            f"{failure} (geomean {g:.3f} < floor {floor}): "
            f"{ {k: round(v, 3) for k, v in ratios.items()} }")
    return g


def ceiling_gate(ratios: dict, ceiling: float, *, what: str,
                 failure: str) -> float:
    """Slowdown-geomean ceiling: print the verdict line, raise
    ``SystemExit`` when the geomean exceeds ``ceiling``.  Returns the
    geomean."""
    g = geomean(ratios.values())
    print(f"# geomean {what}: {g:.3f} (gate {ceiling})")
    if g > ceiling:
        raise SystemExit(f"{failure} {g:.3f} exceeds the {ceiling} gate")
    return g


def scaling_gate(points, *, rate_key: str, label_key: str,
                 label_name: str, reason: str, tol: float = 0.9,
                 min_scaling: float = 1.1,
                 scaling_failure: str = "") -> float:
    """Monotone-scaling gate along a sweep: every step may drop at most
    ``1 - tol`` vs its predecessor, and the last point must be at least
    ``min_scaling``x the first.  Raises ``SystemExit``; returns the
    top-vs-first scaling ratio."""
    for prev, cur in zip(points, points[1:]):
        if cur[rate_key] < tol * prev[rate_key]:
            raise SystemExit(
                f"REGRESSION: tok/s fell from {prev[rate_key]:.1f} "
                f"({label_name}={prev[label_key]}) to "
                f"{cur[rate_key]:.1f} ({label_name}={cur[label_key]}) "
                f"— {reason}")
    scaling = points[-1][rate_key] / points[0][rate_key]
    if scaling < min_scaling:
        raise SystemExit(
            f"REGRESSION: {scaling_failure.format(scaling=scaling)} "
            f"(>= {min_scaling}x required)")
    return scaling
