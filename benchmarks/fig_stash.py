"""Constant-memory stash sweep: stash_every x layers_per_relay x prefetch.

The paper's eq. (4) offloads the boundary stash to the EPS host, but the
stash itself still grows O(N) with depth — one boundary per layer.
``ExecutionConfig.stash_every`` (K) checkpoints only every K-th boundary
(ceil(N/K) stashed) and recomputes the in-between boundaries during the
reverse relay by re-streaming each K-segment's weights forward through
the relay executor — Chen-style sublinear checkpointing composed into
the relay, at one extra layer-forward for K-1 of every K layers.

This benchmark times the l2l-p train step over the {stash_every} x
{layers_per_relay} x {prefetch_depth} grid (weight_stream + offload_stash
on — the eq. (4) scenario the knob refines), pairs every point with its
analytic stash footprint and recompute counts from ``memory_estimate``
(stash = ceil(N/K)*mb*A, recompute_layers, recompute_stops), and writes
``BENCH_stash.json`` at the repo root — the stash-footprint-vs-throughput
frontier in one artifact.

Backend notes: on CPU (this container / CI) memory-space placements are
logical no-ops (``eps.memories_supported``), so the sweep measures the
recompute + schedule overhead of shrinking the stash; the host-DMA
savings side is a TPU observable.

Usage::

    PYTHONPATH=src python benchmarks/fig_stash.py --tiny
    PYTHONPATH=src python -m benchmarks.fig_stash --steps 10
"""
import argparse
import itertools
import json
import os
import sys

if __package__ in (None, ""):                       # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import jax

from benchmarks import gate
from benchmarks.common import lm_batch, time_train_step
from repro import engine as engines
from repro.configs.base import get_config
from repro.core.eps import memories_supported
from repro.core.schedule import ExecutionConfig
from repro.optim import adam

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_stash.json")

# n_layers=6 below: K=4 leaves a remainder segment (6 = 4 + 2), K=2/3
# divide evenly, K=8 > N is the single-checkpoint edge
STASH = (1, 2, 4, 8)
GROUPS = (1, 2)
PREFETCH = (0, 1, 2)


def time_combo(cfg, batch, *, ub, stash, group, prefetch, iters, rounds=3):
    eng = engines.create(
        "l2l-p", cfg,
        ExecutionConfig(n_microbatches=ub, weight_stream=True,
                        offload_stash=True, stash_every=stash,
                        prefetch_depth=prefetch, layers_per_relay=group),
        optimizer=adam(lr=1e-4), donate=False)
    best, compile_s, loss = time_train_step(eng, batch, iters=iters,
                                            rounds=rounds)
    B, S = batch["tokens"].shape
    mem = eng.memory_estimate(batch=B, seq=S)
    return {"stash_every": stash, "layers_per_relay": group,
            "prefetch_depth": prefetch,
            "s_per_step": best,
            "steps_per_s": 1.0 / max(best, 1e-12),
            "compile_s": round(compile_s, 3),
            "loss": loss,
            # the footprint side of the frontier (analytic, eq. 4 with
            # the every-K stash): ceil(N/K) boundaries + recompute price
            "stash_bytes": mem.stash,
            "stash_boundaries": mem.stash_boundaries,
            "recompute_layers": mem.recompute_layers,
            "recompute_stops": mem.recompute_stops,
            "total_device_bytes": mem.total_device,
            "total_host_bytes": mem.total_host}


def run(quick=False, *, arch="bert-large", steps=None, batch=None,
        seq=None, ub=None, out_path=DEFAULT_OUT):
    iters = steps or (5 if quick else 8)
    B = batch or (8 if quick else 16)
    S = seq or (64 if quick else 128)
    UB = ub or (4 if quick else 8)
    cfg = get_config(arch, "smoke").replace(n_layers=6)
    data = lm_batch(cfg, B, S)
    prefetches = PREFETCH[:2] if quick else PREFETCH
    groups = GROUPS[:1] if quick else GROUPS

    results = [time_combo(cfg, data, ub=UB, stash=K, group=g, prefetch=k,
                          iters=iters)
               for K, g, k in itertools.product(STASH, groups, prefetches)]

    def rate(K, g, k):
        return gate.rate_lookup(results, stash_every=K,
                                layers_per_relay=g, prefetch_depth=k)

    # recompute slowdown at each (group, prefetch) point: K vs K=1 — the
    # throughput cost of shrinking the stash to ceil(N/K) boundaries
    slowdown_stash = {
        f"s{K}_g{g}_pf{k}": rate(1, g, k) / rate(K, g, k)
        for K, g, k in itertools.product(STASH[1:], groups, prefetches)}
    record = {
        "benchmark": "fig_stash_recompute",
        "backend": jax.default_backend(),
        "memories_supported": memories_supported(),
        "arch": arch, "variant": "smoke", "n_layers": cfg.n_layers,
        "batch": B, "seq": S, "n_microbatches": UB, "timed_steps": iters,
        "results": results,
        "slowdown_stash_vs_every_layer": slowdown_stash,
        "slowdown_stash_geomean": gate.geomean(slowdown_stash.values()),
        "notes": (
            "Each row pairs measured steps/s with the analytic "
            "ceil(N/K)*mb*A stash footprint and the recompute price "
            "(recompute_layers extra layer-forwards over "
            "recompute_stops extra relay stops) — plot stash_bytes vs "
            "steps_per_s for the stash-footprint-vs-throughput "
            "frontier.  On CPU the placements are no-ops, so slowdowns "
            "measure recompute + schedule overhead only; the host-DMA "
            "savings are a TPU observable."),
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)

    print("\n# Constant-memory stash sweep (l2l-p train step)")
    print("stash_every,group,prefetch,s_per_step,steps_per_s,"
          "stash_KiB,boundaries,recompute_layers,compile_s")
    for r in results:
        print(f"{r['stash_every']},{r['layers_per_relay']},"
              f"{r['prefetch_depth']},{r['s_per_step']:.4f},"
              f"{r['steps_per_s']:.2f},{r['stash_bytes']/2**10:.1f},"
              f"{r['stash_boundaries']},{r['recompute_layers']},"
              f"{r['compile_s']}")
    for k, v in sorted(slowdown_stash.items()):
        print(f"# every-layer/K steps/s ({k}): {v:.3f}")
    if not memories_supported():
        print("# NOTE: backend drops memory-space transfers — this sweep "
              "measures recompute/schedule overhead; the smaller host "
              "stash DMA is a TPU observable")
    print(f"# wrote {out_path}")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="smoke shapes + 5 timed steps x3 rounds (CI)")
    ap.add_argument("--arch", default="bert-large")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ub", type=int, default=None)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    return run(quick=args.tiny, arch=args.arch, steps=args.steps,
               batch=args.batch, seq=args.seq, ub=args.ub,
               out_path=args.out)


if __name__ == "__main__":
    main()
