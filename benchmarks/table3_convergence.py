"""Paper Table 3 / Figs 3-4: convergence of L2L vs baseline.

The paper's finding: (a) L2L at batch 32 matches baseline-with-AG at
batch 32 (same math — the curves coincide), and (b) both beat the
baseline that can only fit device batch 2.  Reproduced at smoke scale on
the synthetic GLUE-stand-in task.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import engine as engines
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.optim import adam, make_schedule


def train(engine, batch, ub, steps, seed=0):
    cfg = get_config("bert-large", "smoke")
    opt = adam(lr=2e-3, schedule=make_schedule(2e-3, warmup=10))
    name = "l2l-p" if engine == "l2l" else "baseline"
    eng = engines.create(name, cfg, ExecutionConfig(n_microbatches=ub),
                         optimizer=opt)
    state = eng.init(jax.random.PRNGKey(seed))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=batch, seed=seed))
    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, m = eng.train_step(state, b)
        losses.append(float(m["loss"]))
    return np.asarray(losses)


def run(quick=False):
    steps = 30 if quick else 80
    l2l_32 = train("l2l", batch=32, ub=16, steps=steps)
    ag_32 = train("baseline", batch=32, ub=16, steps=steps)
    base_2 = train("baseline", batch=2, ub=1, steps=steps)
    print("\n# Table 3 / Fig 3-4 — convergence (synthetic task, smoke BERT)")
    print("method,batch,final_loss,mean_last10")
    for name, l in [("l2l", l2l_32), ("baseline_ag", ag_32),
                    ("baseline_bs2", base_2)]:
        print(f"{name},{32 if name != 'baseline_bs2' else 2},"
              f"{l[-1]:.4f},{l[-10:].mean():.4f}")
    k = min(25, steps)   # beyond ~50 steps fp-reassociation noise is
    # amplified chaotically by the optimizer; exact step-level equivalence
    # is asserted separately (tests/test_equivalence.py)
    dev = float(np.max(np.abs(l2l_32[:k] - ag_32[:k])))
    dev_full = float(np.max(np.abs(l2l_32 - ag_32)))
    print(f"# |L2L - baseline_AG| gap: first {k} steps {dev:.2e}, "
          f"full run {dev_full:.2e} (paper: curves coincide)")
    print(f"# large-batch final {l2l_32[-10:].mean():.3f} vs bs2 "
          f"{base_2[-10:].mean():.3f} (paper: batch 32 converges better)")
    assert dev < 5e-2, "L2L and baseline-AG curves must coincide"
    assert l2l_32[-10:].mean() < base_2[-10:].mean(), \
        "batch-32 L2L should beat the batch-2 baseline"
    return {"gap": dev}


if __name__ == "__main__":
    run()
