"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints CSV blocks per benchmark (see each module's docstring for what the
paper claimed and what we validate).
"""
import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import (cost_model, fig5_time_vs_batch, fig6_breakdown,
                            fig_compile, fig_group, fig_overlap, fig_pack,
                            fig_stash, fig_tier, fig_transport, roofline,
                            table2_memory, table3_convergence,
                            table45_memory_batch)
    benches = [
        ("cost_model_eq5_7", cost_model.run),
        ("table2_memory_vs_depth", table2_memory.run),
        ("table4_5_memory_vs_batch", table45_memory_batch.run),
        ("table3_fig3_4_convergence", table3_convergence.run),
        ("fig5_time_vs_batch", fig5_time_vs_batch.run),
        ("fig6_breakdown", fig6_breakdown.run),
        ("fig_overlap_relay", fig_overlap.run),
        ("fig_pack_relay", fig_pack.run),
        ("fig_group_relay", fig_group.run),
        ("fig_stash_recompute", fig_stash.run),
        ("fig_tier_storage", fig_tier.run),
        ("fig_transport_relay", fig_transport.run),
        ("fig_compile_depth", fig_compile.run),
        ("roofline_from_dryrun", roofline.run),
    ]
    failures = []
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        print(f"\n==== {name} ====")
        t0 = time.time()
        try:
            fn(quick=args.quick)
            print(f"name={name},seconds={time.time()-t0:.1f},status=ok")
        except Exception:
            failures.append(name)
            traceback.print_exc()
            print(f"name={name},seconds={time.time()-t0:.1f},status=FAIL")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    print("\nALL BENCHMARKS OK")


if __name__ == "__main__":
    main()
