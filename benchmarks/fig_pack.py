"""Packed-relay A/B: pack_params x weight_stream x prefetch_depth.

BENCH_relay.json showed the PR-2 double-buffered prefetch pays off with
``weight_stream=off`` but is a wash-to-regression with the real EPS path
on (``weight_stream=on``): the per-leaf relay issues dozens of SMALL
host<->HBM copies per layer, so the transfer side is latency-bound and a
second in-flight slot mostly adds scheduling pressure.  ``pack_params``
attacks exactly that — one large DMA per layer per direction + the fused
flat-segment optimizer — so this benchmark times the l2l-p train step
over all eight {pack, weight_stream, prefetch} combos and writes
``BENCH_pack.json`` at the repo root.

What each axis means by backend:

* CPU (this container / CI): ``weight_stream`` placements are logical
  no-ops (``eps.memories_supported``), so the A/B isolates the pure
  schedule+layout restructuring cost — packed must not regress beyond
  the gate below (the math is bit-identical, tests/test_packing.py).
* TPU: the packed combos replace N-per-leaf host-offload copies with one
  annotate-copy per dtype segment; the ``pack=1, prefetch=1,
  weight_stream=on`` row is the configuration the latency-bound
  BENCH_relay regression should turn into a win.

Usage::

    PYTHONPATH=src python benchmarks/fig_pack.py --tiny
    PYTHONPATH=src python -m benchmarks.fig_pack --steps 10
"""
import argparse
import itertools
import json
import os
import sys

if __package__ in (None, ""):                       # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import jax

from benchmarks import gate
from benchmarks.common import lm_batch, time_train_step
from repro import engine as engines
from repro.configs.base import get_config
from repro.core.eps import memories_supported
from repro.core.schedule import ExecutionConfig
from repro.optim import adam

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_pack.json")

# (pack_params, weight_stream, prefetch_depth)
COMBOS = list(itertools.product((False, True), (False, True), (0, 1)))

# CI gate: a >10% packed-vs-unpacked throughput regression fails the run.
# (Packing is supposed to be free-to-winning; on CPU the placements are
# no-ops so this bounds the pure pack/unpack/fused-optimizer overhead.)
# Gated on the GEOMETRIC MEAN across the (weight_stream, prefetch)
# combos, not the per-combo minimum: on CPU ``weight_stream`` is a no-op
# axis (same program twice), so per-combo ratios carry ~5pp of paired
# measurement noise on shared runners — the PR-3-era record sat at 0.903
# on one combo — while a REAL pack regression moves every combo at once.
REGRESSION_FLOOR = 0.9


def time_combo(cfg, batch, *, ub, pack, weight_stream, prefetch, iters,
               rounds=5):
    # rounds=5 (vs fig_overlap's 3): this benchmark backs a HARD 10% CI
    # gate, so the best-of-rounds minimum gets more shots at a quiet
    # window on shared runners
    eng = engines.create(
        "l2l-p", cfg,
        ExecutionConfig(n_microbatches=ub, weight_stream=weight_stream,
                        offload_stash=weight_stream,
                        prefetch_depth=prefetch, pack_params=pack),
        optimizer=adam(lr=1e-4), donate=False)
    best, compile_s, loss = time_train_step(eng, batch, iters=iters,
                                            rounds=rounds)
    return {"pack_params": pack, "weight_stream": weight_stream,
            "prefetch_depth": prefetch,
            "s_per_step": best,
            "steps_per_s": 1.0 / max(best, 1e-12),
            "compile_s": round(compile_s, 3),
            "loss": loss}


def run(quick=False, *, arch="bert-large", steps=None, batch=None,
        seq=None, ub=None, out_path=DEFAULT_OUT):
    iters = steps or (5 if quick else 8)
    B = batch or (8 if quick else 16)
    S = seq or (64 if quick else 128)
    UB = ub or (4 if quick else 8)
    cfg = get_config(arch, "smoke")
    data = lm_batch(cfg, B, S)

    results = [time_combo(cfg, data, ub=UB, pack=pk, weight_stream=ws,
                          prefetch=pf, iters=iters)
               for pk, ws, pf in COMBOS]

    def rate(pk, ws, pf):
        return gate.rate_lookup(results, pack_params=pk, weight_stream=ws,
                                prefetch_depth=pf)

    # packed vs unpacked at each (weight_stream, prefetch) point — the CI
    # regression gate reads these
    speedup_pack = {
        f"ws_{int(ws)}_pf_{pf}": rate(True, ws, pf) / rate(False, ws, pf)
        for ws, pf in itertools.product((False, True), (0, 1))}
    # prefetch on/off WITHIN each layout — diagnoses the BENCH_relay.json
    # `prefetch=1, weight_stream=on` wash: with per-leaf relays the
    # prefetch has only latency-bound small copies to hide; packed gives
    # it one large DMA per layer to overlap
    speedup_prefetch = {
        f"pack_{int(pk)}_ws_{int(ws)}": rate(pk, ws, 1) / rate(pk, ws, 0)
        for pk, ws in itertools.product((False, True), (False, True))}
    record = {
        "benchmark": "fig_pack_relay",
        "backend": jax.default_backend(),
        "memories_supported": memories_supported(),
        "arch": arch, "variant": "smoke",
        "batch": B, "seq": S, "n_microbatches": UB, "timed_steps": iters,
        "results": results,
        "speedup_packed_vs_unpacked": speedup_pack,
        "speedup_prefetch_on_vs_off": speedup_prefetch,
        "diagnosis": (
            "BENCH_relay.json's prefetch wash at weight_stream=on is the "
            "per-leaf relay's DMA-issue latency: N small copies per layer "
            "leave nothing bandwidth-shaped for the double buffer to "
            "overlap. pack_params coalesces each layer to one copy per "
            "dtype segment; compare speedup_prefetch_on_vs_off pack_1_* "
            "vs pack_0_* (CPU bounds schedule overhead only; the DMA "
            "effect itself is a TPU observable)."),
    }
    print("\n# Packed relay A/B (l2l-p train step)")
    print("pack,weight_stream,prefetch,s_per_step,steps_per_s,compile_s")
    for r in results:
        print(f"{int(r['pack_params'])},{int(r['weight_stream'])},"
              f"{r['prefetch_depth']},{r['s_per_step']:.4f},"
              f"{r['steps_per_s']:.2f},{r['compile_s']}")
    geomean = gate.geomean(speedup_pack.values())
    record["speedup_packed_geomean"] = geomean
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    for k, v in speedup_pack.items():
        print(f"# packed/unpacked steps/s ({k}): {v:.3f}")
    for k, v in speedup_prefetch.items():
        print(f"# prefetch-on/off steps/s ({k}): {v:.3f}")
    if not memories_supported():
        print("# NOTE: backend drops memory-space transfers — this A/B "
              "bounds schedule/layout overhead; the one-DMA-per-layer "
              "win needs TPU")
    print(f"# wrote {out_path}")
    gate.floor_gate(speedup_pack, REGRESSION_FLOOR,
                    what="packed/unpacked",
                    failure="pack_params regressed beyond the 10% gate")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="smoke shapes + 5 timed steps x3 rounds (CI)")
    ap.add_argument("--arch", default="bert-large")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ub", type=int, default=None)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    return run(quick=args.tiny, arch=args.arch, steps=args.steps,
               batch=args.batch, seq=args.seq, ub=args.ub,
               out_path=args.out)


if __name__ == "__main__":
    main()
