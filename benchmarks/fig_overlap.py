"""Relay-overlap A/B: double-buffered EPS prefetch on/off x weight
streaming on/off.

The paper's throughput argument is that the host<->device relay cost is
HIDDEN: "the model is executed a layer at a time across many micro-
batches" with device memory holding "the executing layer(s)'s footprint"
(plural — a compute slot and a transfer slot).  This benchmark times the
L2L-p train step over the four {prefetch_depth, weight_stream} combos and
writes ``BENCH_relay.json`` at the repo root so the perf trajectory has
data points.

What each axis means by backend:

* CPU (this container): ``weight_stream`` placements are logical no-ops
  (see ``repro.core.eps.memories_supported``), so the A/B isolates the
  pure *schedule restructuring* cost — prefetch-on must show NO
  regression (the carry grows by one layer slot; the math is
  bit-identical, tests/test_prefetch.py).
* TPU: the same program text lowers the prefetch slot to host-offload
  annotate custom calls issued one layer AHEAD of their consumer scan
  iteration — the overlap the paper's 40%-over-Megatron claim rests on.

Usage::

    PYTHONPATH=src python benchmarks/fig_overlap.py --tiny
    PYTHONPATH=src python -m benchmarks.fig_overlap --steps 10
"""
import argparse
import json
import os
import sys

if __package__ in (None, ""):                       # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import jax

from benchmarks.common import lm_batch, time_train_step
from repro import engine as engines
from repro.configs.base import get_config
from repro.core.eps import memories_supported
from repro.core.schedule import ExecutionConfig
from repro.optim import adam

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_relay.json")

COMBOS = [  # (prefetch_depth, weight_stream)
    (0, False), (1, False), (0, True), (1, True)]


def time_combo(cfg, batch, *, ub, prefetch, weight_stream, iters,
               rounds=3):
    eng = engines.create(
        "l2l-p", cfg,
        ExecutionConfig(n_microbatches=ub, weight_stream=weight_stream,
                        offload_stash=weight_stream,
                        prefetch_depth=prefetch),
        optimizer=adam(lr=1e-4), donate=False)
    best, compile_s, loss = time_train_step(eng, batch, iters=iters,
                                            rounds=rounds)
    return {"prefetch_depth": prefetch, "weight_stream": weight_stream,
            "s_per_step": best,
            "steps_per_s": 1.0 / max(best, 1e-12),
            "compile_s": round(compile_s, 3),
            "loss": loss}


# a real scheduling regression (e.g. accidentally doubled compute) tanks
# the ratio far below this; CPU timer noise at smoke scale does not
REGRESSION_FLOOR = 0.75


def run(quick=False, *, arch="bert-large", steps=None, batch=None,
        seq=None, ub=None, out_path=DEFAULT_OUT):
    iters = steps or (5 if quick else 8)
    B = batch or (8 if quick else 16)
    S = seq or (64 if quick else 128)
    UB = ub or (4 if quick else 8)
    cfg = get_config(arch, "smoke")
    data = lm_batch(cfg, B, S)

    results = [time_combo(cfg, data, ub=UB, prefetch=pf, weight_stream=ws,
                          iters=iters) for pf, ws in COMBOS]

    def rate(pf, ws):
        return next(r["steps_per_s"] for r in results
                    if r["prefetch_depth"] == pf and r["weight_stream"] == ws)

    speedup = {"weight_stream_off": rate(1, False) / rate(0, False),
               "weight_stream_on": rate(1, True) / rate(0, True)}
    record = {
        "benchmark": "fig_overlap_relay",
        "backend": jax.default_backend(),
        "memories_supported": memories_supported(),
        "arch": arch, "variant": "smoke",
        "batch": B, "seq": S, "n_microbatches": UB, "timed_steps": iters,
        "results": results,
        "speedup_prefetch_on_vs_off": speedup,
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)

    print("\n# Relay overlap A/B (l2l-p train step)")
    print("prefetch,weight_stream,s_per_step,steps_per_s,compile_s")
    for r in results:
        print(f"{r['prefetch_depth']},{int(r['weight_stream'])},"
              f"{r['s_per_step']:.4f},{r['steps_per_s']:.2f},"
              f"{r['compile_s']}")
    for k, v in speedup.items():
        tag = "ok" if v >= REGRESSION_FLOOR else "REGRESSION"
        print(f"# prefetch-on/off steps/s ratio ({k}): {v:.3f} [{tag}]")
    if not memories_supported():
        print("# NOTE: backend drops memory-space transfers — this A/B "
              "isolates schedule-restructuring cost; DMA overlap needs TPU")
    print(f"# wrote {out_path}")
    bad = {k: v for k, v in speedup.items() if v < REGRESSION_FLOOR}
    if bad:
        # RuntimeError (not SystemExit) so benchmarks/run.py's
        # collect-and-continue harness records the failure and keeps going
        raise RuntimeError(
            f"prefetch-on regressed beyond noise floor {REGRESSION_FLOOR}: "
            f"{bad}")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="smoke shapes + 5 timed steps x3 rounds (CI)")
    ap.add_argument("--arch", default="bert-large")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ub", type=int, default=None)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    return run(quick=args.tiny, arch=args.arch, steps=args.steps,
               batch=args.batch, seq=args.seq, ub=args.ub,
               out_path=args.out)


if __name__ == "__main__":
    main()
