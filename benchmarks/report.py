"""Generate the EXPERIMENTS.md data sections from the dry-run/perf JSONs.

    PYTHONPATH=src python -m benchmarks.report > /tmp/report.md
"""
import glob
import json
import os


def load_dir(path):
    recs = []
    for p in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(p) as f:
            recs.append((os.path.basename(p), json.load(f)))
    return recs


def dryrun_table(mesh):
    recs = load_dir(f"experiments/dryrun/baseline/{mesh}")
    out = []
    out.append("| arch | shape | status | compile (s) | device temp (GiB) |"
               " device args (GiB) | collectives (count) |")
    out.append("|---|---|---|---|---|---|---|")
    for _, r in recs:
        if r.get("status") == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | skip | | | | "
                       f"{r['reason'][:70]}… |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | |")
            continue
        m = r["memory"]
        ncoll = sum(v["count"] for v in r.get(
            "collectives", r.get("collectives_scanned", {})).values())
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']} | "
            f"{m['temp_bytes']/2**30:.2f} | "
            f"{m['argument_bytes']/2**30:.2f} | {ncoll} |")
    return "\n".join(out)


def roofline_table(mesh):
    recs = load_dir(f"experiments/dryrun/baseline/{mesh}")
    out = []
    out.append("| arch | shape | compute (ms) | memory (ms) | collective "
               "(ms) | dominant | useful FLOPs | what would move it |")
    out.append("|---|---|---|---|---|---|---|---|")
    for _, r in recs:
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']*1e3:.1f} | "
            f"{rf['memory_s']*1e3:.1f} | {rf['collective_s']*1e3:.2f} | "
            f"**{rf['dominant']}** | {rf['useful_flops_ratio']*100:.0f}% | "
            f"{advice(r)} |")
    return "\n".join(out)


def advice(r):
    rf = r["roofline"]
    arch, shape = r["arch"], r["shape"]
    if rf["dominant"] == "collective":
        if "deepseek" in arch or "grok" in arch:
            return "EP sharding constraint on dispatch (see §Perf)"
        if shape.startswith("decode") or shape == "long_500k":
            return "grouped GQA decode, no kv expansion (§Perf)"
        return "per-layer reduce already eager; reshard logits"
    if rf["dominant"] == "memory":
        if shape == "train_4k":
            return "larger attn chunks / fewer elementwise passes"
        return "bigger per-step batch of work per HBM pass"
    return "MXU-align matmul dims; reduce recompute"


def perf_table():
    recs = load_dir("experiments/dryrun/perf")
    by_pair = {}
    for name, r in recs:
        key = (r["arch"], r["shape"])
        label = name.split("__")[-1].replace(".json", "")
        by_pair.setdefault(key, []).append((label, r))
    out = []
    for (arch, shape), rows in by_pair.items():
        out.append(f"\n### {arch} × {shape}\n")
        out.append("| variant | compute (ms) | memory (ms) | collective "
                   "(ms) | dominant | useful |")
        out.append("|---|---|---|---|---|---|")
        order = {"baseline": 0}
        rows.sort(key=lambda kv: (order.get(kv[0], 1), kv[0]))
        for label, r in rows:
            if r.get("status") != "ok":
                out.append(f"| {label} | ERROR | | | | |")
                continue
            rf = r["roofline"]
            out.append(
                f"| {label} | {rf['compute_s']*1e3:.1f} | "
                f"{rf['memory_s']*1e3:.1f} | {rf['collective_s']*1e3:.2f} | "
                f"{rf['dominant']} | {rf['useful_flops_ratio']*100:.0f}% |")
    return "\n".join(out)


def main():
    for mesh in ("single", "multi"):
        if not glob.glob(f"experiments/dryrun/baseline/{mesh}/*.json"):
            continue
        print(f"\n## Dry-run — {mesh} pod\n")
        print(dryrun_table(mesh))
        if mesh == "single":
            print("\n## Roofline — single pod (16x16, 256 chips)\n")
            print(roofline_table(mesh))
    if glob.glob("experiments/dryrun/perf/*.json"):
        print("\n## Perf variants\n")
        print(perf_table())


if __name__ == "__main__":
    main()
