"""Continuous-batching serve sweep: decode tok/s vs concurrency.

The layer-major serving claim: ONE weight-relay sweep per decode tick
covers every in-flight request, so the per-layer relay overhead (the
dominant serve-time cost under ``weight_stream``) is amortized over the
whole slot pool — decode throughput should grow with concurrency while
per-token latency stays near-flat until the machine saturates.

This benchmark drives a ``ServeEngine`` per concurrency point with a
Poisson load generator (exponential inter-arrival gaps over a mix of
prompt/gen shapes), reports aggregate decode tok/s plus p50/p99
per-token and per-request latency, and writes ``BENCH_serve.json`` at
the repo root.  The run FAILS when scaling breaks: tok/s must be
monotone in concurrency (each point >= 0.9x the previous — paired noise
tolerance) and the top point must beat the single-slot point by >= 1.1x.

Each point compiles its own tick program (max_batch is a static shape),
so a warmup request runs to completion before the timed load starts —
compile time is reported separately, never inside tok/s.

Usage::

    PYTHONPATH=src python benchmarks/fig_serve.py --tiny
    PYTHONPATH=src python -m benchmarks.fig_serve --conc 1 2 4 8
"""
import argparse
import json
import os
import sys

if __package__ in (None, ""):                       # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import time

import jax
import numpy as np

from benchmarks import gate
from repro import engine as engines
from repro.configs.base import get_config
from repro.core.eps import memories_supported
from repro.core.schedule import ExecutionConfig
from repro.serve.engine import ServeConfig

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_serve.json")

# prompt/gen mixes the load generator cycles through (short chat turns,
# longer completions, long-prompt/short-answer)
MIXES = ((8, 16), (16, 24), (24, 8))


def run_point(cfg, exec_cfg, *, conc, n_requests, max_seq, arrival_rate,
              seed=0):
    """Serve ``n_requests`` Poisson arrivals at one concurrency level."""
    eng = engines.create("l2l", cfg, exec_cfg)
    params = eng.model.init_params(jax.random.PRNGKey(seed))
    scfg = ServeConfig(max_batch=conc, page_size=max(1, max_seq // 4),
                       n_pages=4 * conc, max_seq=max_seq)
    srv = eng.serve_session(params, scfg)
    rng = np.random.RandomState(seed + 1)

    # warmup: one request end-to-end compiles the tick
    t0 = time.perf_counter()
    srv.submit(rng.randint(0, cfg.vocab_size, size=(8,)), 4)
    srv.run()
    compile_s = time.perf_counter() - t0

    # Poisson arrivals over the prompt/gen mix
    gaps = rng.exponential(1.0 / arrival_rate, size=n_requests)
    arrivals = np.cumsum(gaps)
    reqs, nxt = [], 0
    t_start = time.perf_counter()
    while nxt < n_requests or not srv.scheduler.idle:
        now = time.perf_counter() - t_start
        while nxt < n_requests and arrivals[nxt] <= now:
            L, G = MIXES[nxt % len(MIXES)]
            reqs.append(srv.submit(
                rng.randint(0, cfg.vocab_size, size=(L,)), G,
                seed=seed + nxt))
            nxt += 1
        if srv.scheduler.idle:
            continue                    # waiting on the next arrival
        srv.tick()
    elapsed = time.perf_counter() - t_start

    n_tok = sum(len(r.generated) for r in reqs)
    req_lat = [r.t_done - r.t_submit for r in reqs]
    tok_lat = [b - a for r in reqs
               for a, b in zip(r.token_times, r.token_times[1:])]
    pct = lambda xs, q: float(np.percentile(xs, q)) if xs else 0.0
    return {"concurrency": conc, "n_requests": n_requests,
            "tokens": n_tok, "elapsed_s": elapsed,
            "tok_per_s": n_tok / max(elapsed, 1e-9),
            "compile_s": round(compile_s, 3),
            "ticks": srv.n_ticks,
            "tok_latency_p50_ms": 1e3 * pct(tok_lat, 50),
            "tok_latency_p99_ms": 1e3 * pct(tok_lat, 99),
            "req_latency_p50_s": pct(req_lat, 50),
            "req_latency_p99_s": pct(req_lat, 99)}


def run(quick=False, *, arch="granite-3-8b", conc=None, requests=None,
        out_path=DEFAULT_OUT):
    concs = conc or ((1, 2, 4) if quick else (1, 2, 4, 8))
    assert len(concs) >= 3, "scaling gate needs >= 3 concurrency points"
    cfg = get_config(arch, "smoke")
    exec_cfg = ExecutionConfig(weight_stream=True)
    max_seq = 48

    results = []
    for c in concs:
        # offered load scales with capacity so every point saturates; the
        # request count scales too so the steady-state dominates ramp-up
        n = requests or (4 * c if quick else 6 * c)
        results.append(run_point(cfg, exec_cfg, conc=c, n_requests=n,
                                 max_seq=max_seq, arrival_rate=200.0 * c))

    rates = [r["tok_per_s"] for r in results]
    scaling = rates[-1] / rates[0]
    record = {
        "benchmark": "fig_serve_continuous_batching",
        "backend": jax.default_backend(),
        "memories_supported": memories_supported(),
        "arch": arch, "variant": "smoke",
        "max_seq": max_seq, "mixes": list(MIXES),
        "results": results,
        "scaling_top_vs_single": scaling,
        "notes": (
            "Layer-major continuous batching: one relay sweep per decode "
            "tick serves every in-flight slot, so tok/s grows with "
            "concurrency while the per-tick relay DMA count stays fixed "
            "(memory_model.estimate_serve: relay_stops_per_tick).  On "
            "CPU the EPS placements are logical no-ops; the amortized "
            "DMA itself is a TPU observable, the batching scaling is "
            "measured here."),
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)

    print("\n# Continuous-batching serve sweep")
    print("concurrency,requests,tokens,tok_per_s,tok_p50_ms,tok_p99_ms,"
          "req_p50_s,req_p99_s,compile_s")
    for r in results:
        print(f"{r['concurrency']},{r['n_requests']},{r['tokens']},"
              f"{r['tok_per_s']:.1f},{r['tok_latency_p50_ms']:.2f},"
              f"{r['tok_latency_p99_ms']:.2f},{r['req_latency_p50_s']:.3f},"
              f"{r['req_latency_p99_s']:.3f},{r['compile_s']}")
    print(f"# top-vs-single scaling: {scaling:.2f}x")
    print(f"# wrote {out_path}")

    # regression gate: concurrency must BUY throughput
    gate.scaling_gate(
        results, rate_key="tok_per_s", label_key="concurrency",
        label_name="conc", reason="continuous batching is not scaling",
        min_scaling=1.1,
        scaling_failure="top concurrency only {scaling:.2f}x the "
                        "single-slot rate")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="3 concurrency points, 4x requests each (CI)")
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--conc", type=int, nargs="+", default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    return run(quick=args.tiny, arch=args.arch, conc=args.conc,
               requests=args.requests, out_path=args.out)


if __name__ == "__main__":
    main()
