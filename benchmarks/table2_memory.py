"""Paper Table 2: memory vs depth — "L2L never runs out of memory even at
96 layers while every other approach OOMs".

Two measurements per depth (12/24/48/96):
 * compiled ``memory_analysis().temp_size_in_bytes`` of Alg-1 baseline vs
   the L2L step (compile-only on one device, full BERT width, batch 32,
   seq 512 — nothing is allocated), and
 * the analytic two-tier model (eqs. 1-4) giving device vs EPS bytes on
   the TPU target (where stash offload is physical).

Validation: baseline activations grow ~linearly with depth; the L2L device
footprint stays ~flat (its growth is only the boundary stash, which
eq. (4) moves to the host).
"""
from benchmarks.common import abstract_batch, bert_model, compiled_memory, gb
from repro import engine as engines
from repro.core.schedule import ExecutionConfig


BATCH, SEQ, UB = 32, 512, 8
DEPTHS = [12, 24, 48, 96]


def run(quick=False):
    rows = []
    depths = DEPTHS[:2] if quick else DEPTHS
    for n in depths:
        model = bert_model(n_layers=n)
        cfg = model.cfg
        params_abs = model.abstract_params()
        batch_abs = abstract_batch(cfg, BATCH, SEQ)

        e_base = engines.create("baseline", model,
                                ExecutionConfig(n_microbatches=1))
        m_base = compiled_memory(e_base.grads_fn, params_abs, batch_abs)

        # compiled measurement: stash on device (the depth-growing term we
        # want visible); analytic: eq. (4)'s host-offloaded L2L-p split
        e_l2l = engines.create("l2l", model,
                               ExecutionConfig(n_microbatches=UB))
        m_l2l = compiled_memory(e_l2l.grads_fn, params_abs, batch_abs)

        a_base = e_base.memory_estimate(batch=BATCH, seq=SEQ)
        a_l2l = engines.create(
            "l2l-p", model, ExecutionConfig(n_microbatches=UB,
                                            offload_stash=True)
        ).memory_estimate(batch=BATCH, seq=SEQ)
        rows.append({
            "layers": n,
            "baseline_temp_gb": gb(m_base["temp"]),
            "l2l_temp_gb": gb(m_l2l["temp"]),
            "analytic_baseline_device_gb": gb(a_base.total_device
                                              + a_base.opt_state),
            "analytic_l2l_device_gb": gb(a_l2l.total_device),
            "analytic_l2l_host_gb": gb(a_l2l.total_host),
        })
    print("\n# Table 2 — memory vs depth (BERT width, batch 32, seq 512)")
    print("layers,baseline_temp_gb,l2l_temp_gb,analytic_base_dev_gb,"
          "analytic_l2l_dev_gb,analytic_l2l_host_gb")
    for r in rows:
        print(f"{r['layers']},{r['baseline_temp_gb']:.2f},"
              f"{r['l2l_temp_gb']:.2f},"
              f"{r['analytic_baseline_device_gb']:.2f},"
              f"{r['analytic_l2l_device_gb']:.2f},"
              f"{r['analytic_l2l_host_gb']:.2f}")
    # paper claim: baseline grows ~linearly, l2l device ~flat
    if len(rows) >= 2:
        g_base = rows[-1]["baseline_temp_gb"] / max(
            rows[0]["baseline_temp_gb"], 1e-9)
        g_l2l_dev = (rows[-1]["analytic_l2l_device_gb"]
                     / max(rows[0]["analytic_l2l_device_gb"], 1e-9))
        depth_ratio = rows[-1]["layers"] / rows[0]["layers"]
        print(f"# baseline temp growth x{g_base:.1f} vs depth x"
              f"{depth_ratio:.0f}; L2L device growth x{g_l2l_dev:.2f} "
              f"(constant-memory claim)")
    return rows


if __name__ == "__main__":
    run()
