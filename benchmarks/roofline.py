"""Roofline aggregation: read experiments/dryrun JSONs -> markdown table
with the three terms, dominant bottleneck, MODEL_FLOPS ratio, and the
hillclimb candidate selection (worst roofline fraction / most
collective-bound / most representative of the paper's technique)."""
import glob
import json
import os


def load(tag="baseline", mesh="single", root="experiments/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(root, tag, mesh, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def table(recs, title=""):
    lines = []
    lines.append(f"\n## Roofline — {title}\n")
    lines.append("| arch | shape | compute (ms) | memory (ms) | "
                 "collective (ms) | dominant | coll. bytes/dev | "
                 "useful FLOPs | device temp (GiB) |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r.get('status')}: "
                         f"{r.get('reason', r.get('error', ''))[:60]} |  |  |  |")
            continue
        rf = r["roofline"]
        mem = r["memory"]["temp_bytes"] / (1 << 30)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']*1e3:.2f} | "
            f"{rf['memory_s']*1e3:.2f} | {rf['collective_s']*1e3:.3f} | "
            f"**{rf['dominant']}** | "
            f"{rf['collective_bytes_per_dev']/1e6:.1f} MB | "
            f"{rf['useful_flops_ratio']*100:.0f}% | {mem:.2f} |")
    return "\n".join(lines)


def pick_hillclimb(recs):
    """worst useful-FLOPs ratio, most collective-bound, most
    L2L-representative (train_4k with the largest relayed layer)."""
    ok = [r for r in recs if r.get("status") == "ok"]
    worst = min(ok, key=lambda r: r["roofline"]["useful_flops_ratio"]
                if r["meta"]["kind"] == "train" else 1e9)
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"])
    train = [r for r in ok if r["meta"]["kind"] == "train"]
    rep = max(train, key=lambda r: r["cost"]["flops"])
    return {"worst_useful": (worst["arch"], worst["shape"]),
            "most_collective": (coll["arch"], coll["shape"]),
            "representative": (rep["arch"], rep["shape"])}


def run(quick=False):
    for mesh in ("single", "multi"):
        recs = load(mesh=mesh)
        if not recs:
            print(f"# no dryrun records for mesh={mesh} — run "
                  f"`python -m repro.launch.dryrun --mesh "
                  f"{'multi' if mesh == 'multi' else 'single'}` first")
            continue
        ok = sum(1 for r in recs if r.get("status") == "ok")
        skip = sum(1 for r in recs if r.get("status") == "skip")
        print(f"\n# Roofline {mesh}: {ok} ok / {skip} skip / "
              f"{len(recs)-ok-skip} error")
        if mesh == "single" and ok:
            print("arch,shape,compute_ms,memory_ms,collective_ms,dominant")
            for r in recs:
                if r.get("status") != "ok":
                    continue
                rf = r["roofline"]
                print(f"{r['arch']},{r['shape']},"
                      f"{rf['compute_s']*1e3:.2f},"
                      f"{rf['memory_s']*1e3:.2f},"
                      f"{rf['collective_s']*1e3:.3f},{rf['dominant']}")
            print("# hillclimb candidates:", pick_hillclimb(recs))
    return True


if __name__ == "__main__":
    run()
