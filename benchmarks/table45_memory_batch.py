"""Paper Tables 4 & 5: L2L memory vs batch size and vs microbatch count.

Table 4's finding: memory grows ~linearly with batch (the stash term
N*mb*A dominates).  Table 5's finding: for fixed batch, the number of
microbatches barely matters (7020 -> 7432 MB for ub 2 -> 16, ~6%).
Both reproduced via compiled memory_analysis + the eq. (2)/(4) model.
"""
from benchmarks.common import abstract_batch, bert_model, compiled_memory, gb
from repro import engine as engines
from repro.core.schedule import ExecutionConfig

SEQ = 512


def run(quick=False):
    model = bert_model(n_layers=8 if quick else 24)
    cfg = model.cfg
    params_abs = model.abstract_params()

    def l2l_engine(ub):
        return engines.create("l2l", model,
                              ExecutionConfig(n_microbatches=ub))

    print("\n# Table 4 — L2L memory vs batch (uB size 4)")
    print("batch,ubatches,temp_gb,analytic_device_gb,analytic_stash_gb")
    batches = [4, 8, 16, 32]
    t4 = []
    for b in (batches[:2] if quick else batches):
        ub = max(1, b // 4)
        eng = l2l_engine(ub)
        m = compiled_memory(eng.grads_fn, params_abs,
                            abstract_batch(cfg, b, SEQ))
        a = eng.memory_estimate(batch=b, seq=SEQ)
        t4.append((b, m["temp"]))
        print(f"{b},{ub},{gb(m['temp']):.3f},{gb(a.total_device):.3f},"
              f"{gb(a.stash):.3f}")

    print("\n# Table 5 — L2L memory vs microbatch count (batch 32)")
    print("batch,ub_size,ubatches,temp_gb,analytic_device_gb")
    t5 = []
    sizes = [2, 4] if quick else [2, 4, 8, 16]
    for ub_size in sizes:
        ub = 32 // ub_size
        eng = l2l_engine(ub)
        m = compiled_memory(eng.grads_fn, params_abs,
                            abstract_batch(cfg, 32, SEQ))
        a = eng.memory_estimate(batch=32, seq=SEQ)
        t5.append(m["temp"])
        print(f"32,{ub_size},{ub},{gb(m['temp']):.3f},"
              f"{gb(a.total_device):.3f}")
    if len(t5) > 1:
        spread = (max(t5) - min(t5)) / max(min(t5), 1)
        print(f"# ub-count sensitivity: {spread*100:.1f}% "
              f"(paper Table 5: ~6%)")
    return {"t4": t4, "t5": t5}


if __name__ == "__main__":
    run()
