"""§3.1.2 worked example: the paper computes Baseline=2.05s, L2L=2.92s,
L2L-p=2.45s for BERT-Large on a V100.  We implement eqs. (5)-(7) exactly
with the paper's stated constants and check the three numbers, then apply
the same model to every assigned architecture on the TPU v5e target.
"""
from repro.configs.base import get_config, list_archs
from repro.core.memory_model import for_config, paper_worked_example
from repro.models.model import LayeredModel


def run(quick=False):
    tm = paper_worked_example()
    b, l, lp = tm.baseline(), tm.l2l(), tm.l2l_p()
    print("\n# Cost model — paper §3.1.2 worked example (eqs. 5-7)")
    print("method,model_s,paper_s")
    print(f"baseline,{b:.2f},2.05")
    print(f"l2l,{l:.2f},2.92")
    print(f"l2l_p,{lp:.2f},2.45")
    assert abs(l - 2.92) < 0.15, l
    assert abs(lp - 2.45) < 0.15, lp
    # the paper's baseline constant is ~10% above eq.(5) with its own
    # inputs (2.05 vs ~1.85) — we report our exact evaluation.
    assert abs(b - 2.05) < 0.3, b
    print(f"# ordering reproduced: baseline < L2L-p < L2L "
          f"({b:.2f} < {lp:.2f} < {l:.2f})")

    if not quick:
        print("\n# same model, assigned archs on TPU v5e "
              "(train_4k per-chip share, u=4)")
        print("arch,baseline_s,l2l_s,l2l_p_s,l2lp_overhead_pct")
        for arch in list_archs():
            if arch == "bert-large":
                continue
            cfg = get_config(arch)
            model = LayeredModel(cfg)
            t = for_config(model, batch=16, seq=4096, u=4)
            bb, ll, pp = t.baseline(), t.l2l(), t.l2l_p()
            print(f"{arch},{bb:.3f},{ll:.3f},{pp:.3f},"
                  f"{100*(pp-bb)/bb:.1f}")
    return {"baseline": b, "l2l": l, "l2l_p": lp}


if __name__ == "__main__":
    run()
