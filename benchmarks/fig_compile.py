"""Compile-time-vs-depth: the segment-scan program against the unrolled.

The paper's constant-memory claim is about runtime bytes, but the
COMPILED PROGRAM used to grow with depth too: the K > 1 stash schedule
unrolled one relay per segment per phase (~3*ceil(N/K) scan instances),
so trace/lower/compile seconds scaled linearly with N — the cost a
100-layer sweep or a NAS growth loop pays on every step.  The
``segment_scan`` driver collapses each phase to ONE outer lax.scan, so
program size and compile time are O(1) in depth.

This benchmark times jit trace+lower and XLA compile of the l2l-p train
step across a depth sweep for both drivers (``segment_scan`` True/False
at K=2, G=2, prefetch=1), records the lowered while-instance count and
the memory model's ``relay_instances`` accounting next to each point,
and writes ``BENCH_compile.json`` at the repo root.  The gate: the
segment-scan program's deepest-vs-shallowest compile-time ratio must
stay flat (ceiling), while the unrolled driver documents the blowup.

Usage::

    PYTHONPATH=src python benchmarks/fig_compile.py --tiny
    PYTHONPATH=src python -m benchmarks.fig_compile --depths 4,8,16,32
"""
import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):                       # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import jax
import numpy as np

from benchmarks import gate
from benchmarks.common import lm_batch
from repro import engine as engines
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig
from repro.optim import adam

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_compile.json")

# deepest/shallowest segment-scan compile-time ratio must stay below
# this: the program is depth-invariant, so only XLA noise remains
# (measured ~1.0-1.2 on CPU CI; the unrolled driver measures 4-10x over
# the same sweep)
FLATNESS_CEILING = 1.8


def time_point(cfg, batch, *, segment_scan, stash, group, prefetch, ub):
    eng = engines.create(
        "l2l-p", cfg,
        ExecutionConfig(n_microbatches=ub, weight_stream=True,
                        offload_stash=True, stash_every=stash,
                        layers_per_relay=group, prefetch_depth=prefetch,
                        segment_scan=segment_scan),
        optimizer=adam(lr=1e-4), donate=False)
    state = eng.abstract_state()
    batch_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), a.dtype), batch)
    t0 = time.time()
    lowered = jax.jit(eng.step_fn).lower(state, batch_abs)
    trace_lower_s = time.time() - t0
    hlo = lowered.as_text()
    t0 = time.time()
    lowered.compile()
    compile_s = time.time() - t0
    B, S = batch["tokens"].shape
    mem = eng.memory_estimate(batch=B, seq=S)
    return {"n_layers": cfg.n_layers, "segment_scan": segment_scan,
            "stash_every": stash, "layers_per_relay": group,
            "prefetch_depth": prefetch,
            "trace_lower_s": round(trace_lower_s, 3),
            "compile_s": round(compile_s, 3),
            "total_s": round(trace_lower_s + compile_s, 3),
            "while_instances": hlo.count("stablehlo.while"),
            "relay_instances": mem.relay_instances}


def run(quick=False, *, arch="bert-large", depths=None,
        out_path=DEFAULT_OUT):
    depths = depths or ((4, 8, 16) if quick else (4, 8, 16, 32))
    K, G, PF, UB = 2, 2, 1, 2
    base = get_config(arch, "smoke")
    batch = lm_batch(base, 4, 32)
    results = []
    for seg in (True, False):
        for n in depths:
            r = time_point(base.replace(n_layers=n), batch,
                           segment_scan=seg, stash=K, group=G,
                           prefetch=PF, ub=UB)
            results.append(r)
            print(f"seg={seg} n={n}: trace+lower {r['trace_lower_s']}s "
                  f"compile {r['compile_s']}s "
                  f"while={r['while_instances']} "
                  f"relays={r['relay_instances']}", flush=True)

    def row(seg, n, key):
        return gate.rate_lookup(results, key=key, segment_scan=seg,
                                n_layers=n)

    lo, hi = depths[0], depths[-1]
    flatness = {
        "trace_lower_deep_vs_shallow":
            row(True, hi, "trace_lower_s") / row(True, lo, "trace_lower_s"),
        "compile_deep_vs_shallow":
            row(True, hi, "compile_s") / row(True, lo, "compile_s")}
    blowup = {f"n{n}": row(False, n, "total_s") / row(True, n, "total_s")
              for n in depths}
    record = {
        "benchmark": "fig_compile_depth",
        "backend": jax.default_backend(),
        "arch": arch, "variant": "smoke",
        "depths": list(depths),
        "stash_every": K, "layers_per_relay": G, "prefetch_depth": PF,
        "results": results,
        "segment_scan_flatness": flatness,
        "unrolled_over_scan_total_s": blowup,
        "while_instances_depth_invariant": (
            row(True, lo, "while_instances")
            == row(True, hi, "while_instances")),
        "notes": (
            "trace_lower_s = jit trace + StableHLO lowering; compile_s = "
            "XLA compile of the lowered module.  segment_scan=True keeps "
            "the while-instance count and both times flat across the "
            "depth sweep; segment_scan=False re-emits the historical "
            "~3*ceil(N/K)-relay program whose times grow linearly — the "
            "depth-proportional blowup this driver removed."),
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)

    print("\n# Compile time vs depth (l2l-p train step, K=2 G=2 pf=1)")
    print("segment_scan,n_layers,trace_lower_s,compile_s,while,relays")
    for r in results:
        print(f"{r['segment_scan']},{r['n_layers']},"
              f"{r['trace_lower_s']},{r['compile_s']},"
              f"{r['while_instances']},{r['relay_instances']}")
    for n, v in sorted(blowup.items()):
        print(f"# unrolled/scan total seconds ({n}): {v:.2f}x")
    assert record["while_instances_depth_invariant"], (
        "segment-scan while count varies with depth: "
        + str([(r["n_layers"], r["while_instances"])
               for r in results if r["segment_scan"]]))
    gate.ceiling_gate(
        flatness, FLATNESS_CEILING,
        what="segment-scan compile-time growth deep-vs-shallow",
        failure="REGRESSION: segment-scan compile time grows with depth —")
    print(f"# wrote {out_path}")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="3-depth sweep (CI)")
    ap.add_argument("--arch", default="bert-large")
    ap.add_argument("--depths", default=None,
                    help="comma-separated depth sweep override")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    depths = (tuple(int(d) for d in args.depths.split(","))
              if args.depths else None)
    return run(quick=args.tiny, arch=args.arch, depths=depths,
               out_path=args.out)


if __name__ == "__main__":
    main()
