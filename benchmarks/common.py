"""Shared benchmark helpers."""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models.model import LayeredModel


def timeit(fn, *args, iters=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def time_train_step(eng, batch, *, iters, rounds=3):
    """Compile + best-of-N-rounds steady-state timing of an engine's
    train step (shared by the fig_overlap / fig_pack A/B harnesses).
    Best-of-rounds: a background spike on a shared runner slows one
    round, not the minimum.  Returns (best_s_per_step, compile_s,
    final_loss)."""
    state = eng.init(jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    state, m = eng.train_step(state, batch)          # compile + step 0
    jax.block_until_ready(m["loss"])
    compile_s = time.perf_counter() - t0
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(iters):
            state, m = eng.train_step(state, batch)
        jax.block_until_ready(m["loss"])
        best = min(best, (time.perf_counter() - t0) / iters)
    return best, compile_s, float(m["loss"])


def bert_model(n_layers=24, d_model=1024, variant="full"):
    cfg = get_config("bert-large", variant).replace(
        n_layers=n_layers, d_model=d_model,
        n_heads=max(1, d_model // 64), n_kv_heads=max(1, d_model // 64),
        d_ff=4 * d_model)
    return LayeredModel(cfg)


def lm_batch(cfg, batch, seq, seed=0):
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                  global_batch=batch, seed=seed))
    return {k: jnp.asarray(v) for k, v in data.batch(0).items()}


def abstract_batch(cfg, batch, seq):
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "targets": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "mask": jax.ShapeDtypeStruct((batch, seq), jnp.float32),
    }


def compiled_memory(fn, *abstract_args):
    """Lower+compile on the single default device; return memory stats."""
    lo = jax.jit(fn).lower(*abstract_args)
    co = lo.compile()
    ma = co.memory_analysis()
    return {"temp": ma.temp_size_in_bytes,
            "args": ma.argument_size_in_bytes,
            "out": ma.output_size_in_bytes}


def gb(x):
    return x / (1 << 30)


def row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")
