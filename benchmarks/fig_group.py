"""Layer-group relay sweep: layers_per_relay x prefetch_depth x pack.

The paper's §3.1 device footprint is "the executing **layer(s)**" —
plural: the unified relay executor makes that a free knob.  Relaying G
stacked layers per stop trades a G·(1 + prefetch_depth) layer-slot HBM
footprint for ceil(N/G) relay stops (fewer, larger DMAs — the
MegaTrain-style transfer-batching axis), while k-deep prefetch overlaps
up to k of those transfers with compute.  This benchmark times the l2l-p
train step over the {layers_per_relay} x {prefetch_depth} x {pack_params}
grid (weight_stream on — the EPS scenario where the tradeoff exists),
pairs every point with its analytic device/EPS footprint from
``memory_estimate`` (eqs. 2/3 with the G·(1+k) transit term), and writes
``BENCH_group.json`` at the repo root — the paper's
footprint-vs-throughput curve in one artifact.

Backend notes: on CPU (this container / CI) memory-space placements are
logical no-ops (``eps.memories_supported``), so the sweep bounds the pure
schedule/layout restructuring cost and checks nothing regresses; the DMA
batching effect itself is a TPU observable.

Usage::

    PYTHONPATH=src python benchmarks/fig_group.py --tiny
    PYTHONPATH=src python -m benchmarks.fig_group --steps 10
"""
import argparse
import itertools
import json
import os
import sys

if __package__ in (None, ""):                       # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import jax

from benchmarks import gate
from benchmarks.common import lm_batch, time_train_step
from repro import engine as engines
from repro.configs.base import get_config
from repro.core.eps import memories_supported
from repro.core.schedule import ExecutionConfig
from repro.optim import adam

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_group.json")

GROUPS = (1, 2, 4)
PREFETCH = (0, 1, 2)
PACKS = (False, True)


def time_combo(cfg, batch, *, ub, group, prefetch, pack, iters, rounds=3):
    eng = engines.create(
        "l2l-p", cfg,
        ExecutionConfig(n_microbatches=ub, weight_stream=True,
                        offload_stash=True, prefetch_depth=prefetch,
                        layers_per_relay=group, pack_params=pack),
        optimizer=adam(lr=1e-4), donate=False)
    best, compile_s, loss = time_train_step(eng, batch, iters=iters,
                                            rounds=rounds)
    B, S = batch["tokens"].shape
    mem = eng.memory_estimate(batch=B, seq=S)
    return {"layers_per_relay": group, "prefetch_depth": prefetch,
            "pack_params": pack,
            "s_per_step": best,
            "steps_per_s": 1.0 / max(best, 1e-12),
            "compile_s": round(compile_s, 3),
            "loss": loss,
            # the footprint side of the curve (analytic, eqs. 2/3):
            # G*(1+k) layer slots on device, ceil(N/G) relay stops
            "params_device_bytes": mem.params_device,
            "total_device_bytes": mem.total_device,
            "total_host_bytes": mem.total_host,
            "relay_stops": mem.relay_stops,
            "relay_copies_weights": mem.relay_copies_weights,
            "relay_copies_opt": mem.relay_copies_opt}


def run(quick=False, *, arch="bert-large", steps=None, batch=None,
        seq=None, ub=None, out_path=DEFAULT_OUT):
    iters = steps or (5 if quick else 8)
    B = batch or (8 if quick else 16)
    S = seq or (64 if quick else 128)
    UB = ub or (4 if quick else 8)
    # n_layers=6 keeps the smoke sweep honest: G=4 leaves a remainder
    # stop (6 = 4 + 2) and G=2 divides evenly
    cfg = get_config(arch, "smoke").replace(n_layers=6)
    data = lm_batch(cfg, B, S)
    prefetches = PREFETCH[:2] if quick else PREFETCH

    results = [time_combo(cfg, data, ub=UB, group=g, prefetch=k, pack=pk,
                          iters=iters)
               for g, k, pk in itertools.product(GROUPS, prefetches, PACKS)]

    def rate(g, k, pk):
        return gate.rate_lookup(results, layers_per_relay=g,
                                prefetch_depth=k, pack_params=pk)

    # grouping speedup at each (prefetch, pack) point: G vs G=1 — the
    # throughput side of the footprint-vs-throughput curve
    speedup_group = {
        f"g{g}_pf{k}_pack{int(pk)}": rate(g, k, pk) / rate(1, k, pk)
        for g, k, pk in itertools.product(GROUPS[1:], prefetches, PACKS)}
    record = {
        "benchmark": "fig_group_relay",
        "backend": jax.default_backend(),
        "memories_supported": memories_supported(),
        "arch": arch, "variant": "smoke", "n_layers": cfg.n_layers,
        "batch": B, "seq": S, "n_microbatches": UB, "timed_steps": iters,
        "results": results,
        "speedup_group_vs_single": speedup_group,
        "speedup_group_geomean": gate.geomean(speedup_group.values()),
        "notes": (
            "Each row pairs measured steps/s with the analytic "
            "G*(1+prefetch) device footprint and ceil(N/G) relay-stop "
            "count — plot params_device_bytes vs steps_per_s for the "
            "paper's footprint-vs-throughput curve.  On CPU the "
            "placements are no-ops, so ratios bound schedule/layout "
            "overhead only; the fewer-larger-DMAs win is a TPU "
            "observable."),
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)

    print("\n# Layer-group relay sweep (l2l-p train step)")
    print("group,prefetch,pack,s_per_step,steps_per_s,"
          "params_device_MiB,relay_stops,compile_s")
    for r in results:
        print(f"{r['layers_per_relay']},{r['prefetch_depth']},"
              f"{int(r['pack_params'])},{r['s_per_step']:.4f},"
              f"{r['steps_per_s']:.2f},"
              f"{r['params_device_bytes']/2**20:.1f},{r['relay_stops']},"
              f"{r['compile_s']}")
    for k, v in sorted(speedup_group.items()):
        print(f"# group/single steps/s ({k}): {v:.3f}")
    if not memories_supported():
        print("# NOTE: backend drops memory-space transfers — this sweep "
              "bounds schedule/layout overhead; the one-DMA-per-G-layers "
              "win needs TPU")
    print(f"# wrote {out_path}")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="smoke shapes + 5 timed steps x3 rounds (CI)")
    ap.add_argument("--arch", default="bert-large")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ub", type=int, default=None)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    return run(quick=args.tiny, arch=args.arch, steps=args.steps,
               batch=args.batch, seq=args.seq, ub=args.ub,
               out_path=args.out)


if __name__ == "__main__":
    main()
