"""Paper Fig 6: L2L step-time breakdown (forward / backward / optimizer /
transfer).  The paper measured 19% fwd, 49% bwd, 25% optimizer, 7%
transfers at batch 32, ub 8 — with the optimizer share its motivation for
the multi-process (L2L-p) version and, here, for the fused-Adam Pallas
kernel.

CPU measurement: phase times via nested jits (fwd-only, fwd+bwd, full
step); transfer share comes from the eq. (6) relay term on the TPU target
(CPU has no host link to time).
"""
import jax

from benchmarks.common import lm_batch, timeit
from repro import engine as engines
from repro.configs.base import get_config
from repro.core.memory_model import for_config
from repro.core.schedule import ExecutionConfig
from repro.optim import adam


def run(quick=False):
    cfg = get_config("bert-large", "smoke")
    eng = engines.create("l2l-p", cfg, ExecutionConfig(n_microbatches=8),
                         optimizer=adam(), donate=False)
    model = eng.model
    state = eng.init(jax.random.PRNGKey(0))
    params = state.params
    batch = lm_batch(cfg, 32, 64)

    t_fwd = timeit(lambda: eng.prefill(
        params, {k: batch[k] for k in ("tokens",)}), iters=3)
    t_grads = timeit(lambda: eng.grads(params, batch), iters=3)
    t_step = timeit(lambda: eng.train_step(state, batch), iters=3)
    t_bwd = max(t_grads - t_fwd, 1e-9)
    t_opt = max(t_step - t_grads, 1e-9)

    print("\n# Fig 6 — L2L step breakdown (batch 32, ub_size 4, smoke)")
    print("phase,seconds,share_pct")
    total = t_fwd + t_bwd + t_opt
    for name, t in [("forward(+recompute)", t_fwd), ("backward", t_bwd),
                    ("optimizer", t_opt)]:
        print(f"{name},{t:.4f},{100*t/total:.1f}")
    tm = for_config(model, batch=32, seq=64, u=8)
    relay = tm.n_layers * 2 * tm.layer_bytes / tm.hb
    print(f"transfer(target-model),{relay:.4f},"
          f"{100*relay/(total+relay):.1f}")
    print("# paper: fwd 19% / bwd 49% / optimizer 25% / transfer 7%")
    return {"fwd": t_fwd, "bwd": t_bwd, "opt": t_opt}


if __name__ == "__main__":
    run()
