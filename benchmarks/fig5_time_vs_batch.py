"""Paper Fig 5: time per epoch vs batch size — L2L overtakes baseline as
batch grows (less frequent updates + better device utilization).

On CPU we measure REAL step wall-clock at smoke scale with the paper's
constraint emulated: the baseline's device microbatch is capped at 2 (its
V100 OOM limit), while L2L runs device microbatches of 8.  Time per
"epoch" = time per step normalized to a fixed token budget.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import lm_batch, timeit
from repro.configs.base import get_config
from repro.core import baseline as base_mod, l2l
from repro.core.schedule import ExecutionConfig
from repro.models.model import LayeredModel
from repro.optim import adam

SEQ = 64


def run(quick=False):
    cfg = get_config("bert-large", "smoke")
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adam()
    batches = [8, 16] if quick else [8, 16, 32, 64]
    print("\n# Fig 5 — time per fixed token budget vs batch "
          "(baseline ub_size=2 cap, L2L ub_size=8)")
    print("batch,baseline_s,l2l_s,ratio")
    out = []
    for b in batches:
        batch = lm_batch(cfg, b, SEQ)
        s_base = jax.jit(base_mod.make_train_step(
            model, opt, ExecutionConfig(n_microbatches=b // 2)))
        s_l2l = jax.jit(l2l.make_train_step(
            model, opt, ExecutionConfig(n_microbatches=max(1, b // 8))))
        st_b = base_mod.init_opt_state(opt, params)
        st_l = l2l.init_opt_state(opt, params)
        tb = timeit(lambda: s_base(params, st_b, batch), iters=2) / b
        tl = timeit(lambda: s_l2l(params, st_l, batch), iters=2) / b
        out.append((b, tb, tl))
        print(f"{b},{tb:.4f},{tl:.4f},{tb/max(tl,1e-12):.2f}")
    # paper claim: the ratio (baseline/L2L) grows with batch
    if len(out) >= 2:
        r0 = out[0][1] / out[0][2]
        r1 = out[-1][1] / out[-1][2]
        print(f"# baseline/L2L per-sample ratio: {r0:.2f} -> {r1:.2f} "
              f"(paper: L2L overtakes as batch grows)")
    return out


if __name__ == "__main__":
    run()
