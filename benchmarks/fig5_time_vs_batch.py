"""Paper Fig 5: time per epoch vs batch size — L2L overtakes baseline as
batch grows (less frequent updates + better device utilization).

On CPU we measure REAL step wall-clock at smoke scale with the paper's
constraint emulated: the baseline's device microbatch is capped at 2 (its
V100 OOM limit), while L2L runs device microbatches of 8.  Time per
"epoch" = time per step normalized to a fixed token budget.
"""
import jax

from benchmarks.common import lm_batch, timeit
from repro import engine as engines
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig
from repro.optim import adam

SEQ = 64


def run(quick=False):
    cfg = get_config("bert-large", "smoke")
    opt = adam()
    batches = [8, 16] if quick else [8, 16, 32, 64]
    print("\n# Fig 5 — time per fixed token budget vs batch "
          "(baseline ub_size=2 cap, L2L ub_size=8)")
    print("batch,baseline_s,l2l_s,ratio")
    out = []
    for b in batches:
        batch = lm_batch(cfg, b, SEQ)
        e_base = engines.create(
            "baseline", cfg, ExecutionConfig(n_microbatches=b // 2),
            optimizer=opt, donate=False)
        e_l2l = engines.create(
            "l2l-p", cfg, ExecutionConfig(n_microbatches=max(1, b // 8)),
            optimizer=opt, donate=False)
        st_b = e_base.init(jax.random.PRNGKey(0))
        st_l = e_l2l.init(jax.random.PRNGKey(0))
        tb = timeit(lambda: e_base.train_step(st_b, batch), iters=2) / b
        tl = timeit(lambda: e_l2l.train_step(st_l, batch), iters=2) / b
        out.append((b, tb, tl))
        print(f"{b},{tb:.4f},{tl:.4f},{tb/max(tl,1e-12):.2f}")
    # paper claim: the ratio (baseline/L2L) grows with batch
    if len(out) >= 2:
        r0 = out[0][1] / out[0][2]
        r1 = out[-1][1] / out[-1][2]
        print(f"# baseline/L2L per-sample ratio: {r0:.2f} -> {r1:.2f} "
              f"(paper: L2L overtakes as batch grows)")
    return out


if __name__ == "__main__":
    run()
