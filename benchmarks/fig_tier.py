"""Storage-tier EPS A/B + verified NVMe streaming throughput.

Two measurements, one artifact (``BENCH_tier.json`` at the repo root):

* **Tier A/B** — the l2l-p train step under three placements per
  prefetch depth: host-only (``tiers=2``), the tier chain with a budget
  that FITS the whole stacked state (``tiers=3``, nothing demoted), and
  the chain fully streamed from disk (``tiers=3, host_budget_bytes=0``:
  every stacked layer row demoted and re-materialized around each
  step).  Staging happens OUTSIDE the jitted program, so all three run
  the same compiled step.  The run FAILS on a >10% geometric-mean
  host-only-vs-tier regression on the FITTING arm — the chain's
  bookkeeping must be free until the disk is actually needed.  The
  fully-streamed arm is reported (slowdown + MiB moved per step), not
  gated: its cost is the disk round-trip itself (pread + per-row crc +
  stage-out write-back), a bandwidth observable that on a smoke-sized
  model cannot hide behind compute.

* **Streamed throughput** — a raw multi-GB SegmentStore soak: layer-row
  sized records written once (staged-fsync-rename), then read back in
  relay-window chunks with every row crc-checked, reporting verified
  write/read MB/s.  This is the number the tier chain's prefetch ring
  amortizes against compute, and the scale (``--gb``) where rot/retry
  machinery earns its keep.

Backend notes: on CPU (this container / CI) memory-space placements are
logical no-ops; the A/B isolates the disk tier's cost because both arms
run the same compiled program either way.

Usage::

    PYTHONPATH=src python benchmarks/fig_tier.py --tiny
    PYTHONPATH=src python -m benchmarks.fig_tier --gb 2.5
"""
import argparse
import json
import os
import shutil
import sys
import tempfile
import time

if __package__ in (None, ""):                       # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import jax
import numpy as np

from benchmarks import gate
from benchmarks.common import lm_batch, time_train_step
from repro import engine as engines
from repro.configs.base import get_config
from repro.core.eps import memories_supported
from repro.core.schedule import ExecutionConfig
from repro.core.tierstore import SegmentStore
from repro.optim import adam

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_tier.json")

PREFETCH = (0, 1, 2)
GATE = 1.10          # tier arm must stay within 10% of host-only steps/s


def time_combo(cfg, batch, *, ub, tiers, prefetch, iters, budget=0,
               tier_dir=None, rounds=3):
    eng = engines.create(
        "l2l-p", cfg,
        ExecutionConfig(n_microbatches=ub, weight_stream=True,
                        offload_stash=True, prefetch_depth=prefetch,
                        pack_params=True, tiers=tiers,
                        host_budget_bytes=budget,
                        tier_dir=tier_dir or ""),
        optimizer=adam(lr=1e-4), donate=False)
    best, compile_s, loss = time_train_step(eng, batch, iters=iters,
                                            rounds=rounds)
    out = {"tiers": tiers, "prefetch_depth": prefetch,
           "host_budget_bytes": budget if tiers >= 3 else None,
           "s_per_step": best,
           "steps_per_s": 1.0 / max(best, 1e-12),
           "compile_s": round(compile_s, 3),
           "loss": loss}
    if eng.tier is not None:
        m = eng.tier.metrics
        out["tier_metrics"] = {k: m[k] for k in
                               ("reads", "read_bytes", "writes",
                                "write_bytes", "demoted_layers",
                                "retries", "effective_depth")}
    return out


def stream_soak(root, *, target_gb, row_mib=8, window_rows=4):
    """Write ~target_gb of layer-row records, read them back in
    relay-window chunks with per-row crc verification; report MB/s for
    BOTH read paths — the zero-copy mmap view (crc over the page cache,
    no userspace buffer) and the pread fallback."""
    w = row_mib * (1 << 20) // 4                     # f32 elems per row
    n = max(window_rows, int(target_gb * (1 << 30)) // (w * 4))
    rng = np.random.default_rng(0)
    segs = {"float32": rng.standard_normal((n, w)).astype(np.float32)}
    nbytes = segs["float32"].nbytes

    st = SegmentStore(root)
    t0 = time.perf_counter()
    st.put("stream_w", segs, step=0)
    write_s = time.perf_counter() - t0

    def read_pass(use_mmap):
        st2 = SegmentStore(root, use_mmap=use_mmap)  # cold manifest cache
        t0 = time.perf_counter()
        read_bytes = 0
        for lo in range(0, n, window_rows):
            hi = min(lo + window_rows, n)
            # crc-checked rows; copy=False keeps the mmap pass zero-copy
            out = st2.read_rows("stream_w", lo, hi, copy=False)
            read_bytes += out["float32"].nbytes
        read_s = time.perf_counter() - t0
        assert read_bytes == nbytes
        return read_s, st2.metrics

    mmap_s, mmap_metrics = read_pass(True)
    pread_s, pread_metrics = read_pass(False)
    used_mmap = mmap_metrics["mmap_reads"] > 0       # platform support
    return {"streamed_gb": round(nbytes / (1 << 30), 3),
            "rows": n, "row_mib": row_mib, "window_rows": window_rows,
            "write_mb_s": round(nbytes / (1 << 20) / max(write_s, 1e-9), 1),
            "verified_read_mb_s":
                round(nbytes / (1 << 20) / max(mmap_s, 1e-9), 1)
                if used_mmap else
                round(nbytes / (1 << 20) / max(pread_s, 1e-9), 1),
            "mmap_read_mb_s":
                round(nbytes / (1 << 20) / max(mmap_s, 1e-9), 1)
                if used_mmap else None,
            "pread_read_mb_s":
                round(nbytes / (1 << 20) / max(pread_s, 1e-9), 1),
            "store_metrics": {k: mmap_metrics[k] + pread_metrics[k]
                              for k in ("reads", "read_bytes", "retries",
                                        "mmap_reads", "pread_reads")}}


def run(quick=False, *, arch="bert-large", steps=None, batch=None,
        seq=None, ub=None, gb=None, out_path=DEFAULT_OUT):
    iters = steps or (5 if quick else 8)
    B = batch or (8 if quick else 16)
    S = seq or (64 if quick else 128)
    UB = ub or (4 if quick else 8)
    target_gb = gb if gb is not None else (0.25 if quick else 2.5)
    cfg = get_config(arch, "smoke").replace(n_layers=6)
    data = lm_batch(cfg, B, S)
    prefetches = PREFETCH[:2] if quick else PREFETCH

    FITS = 1 << 40                       # budget no smoke model exceeds
    scratch = tempfile.mkdtemp(prefix="bench_tier_")
    try:
        results = []
        for k in prefetches:
            results.append(time_combo(cfg, data, ub=UB, tiers=2,
                                      prefetch=k, iters=iters))
            results.append(time_combo(
                cfg, data, ub=UB, tiers=3, prefetch=k, iters=iters,
                budget=FITS, tier_dir=os.path.join(scratch, f"fit{k}")))
            results.append(time_combo(
                cfg, data, ub=UB, tiers=3, prefetch=k, iters=iters,
                budget=0, tier_dir=os.path.join(scratch, f"pf{k}")))
        soak = stream_soak(os.path.join(scratch, "soak"),
                           target_gb=target_gb)
    finally:
        shutil.rmtree(scratch, ignore_errors=True)

    def rate(tiers, k, budget=None):
        if tiers == 2:
            return gate.rate_lookup(results, tiers=2, prefetch_depth=k)
        return gate.rate_lookup(results, tiers=3, prefetch_depth=k,
                                host_budget_bytes=budget)

    slowdown = {f"pf{k}": rate(2, k) / rate(3, k, FITS)
                for k in prefetches}
    streamed = {f"pf{k}": rate(2, k) / rate(3, k, 0) for k in prefetches}
    geomean = gate.geomean(slowdown.values())
    record = {
        "benchmark": "fig_tier_storage",
        "backend": jax.default_backend(),
        "memories_supported": memories_supported(),
        "arch": arch, "variant": "smoke", "n_layers": cfg.n_layers,
        "batch": B, "seq": S, "n_microbatches": UB, "timed_steps": iters,
        "results": results,
        "slowdown_host_only_vs_tier_fits": slowdown,
        "slowdown_host_only_vs_fully_streamed": streamed,
        "slowdown_geomean": geomean,
        "gate": GATE,
        "stream_soak": soak,
        "notes": (
            "l2l-p train step under three placements: host-only "
            "(tiers=2), tier chain with a fitting budget (gated <=10%: "
            "the chain is free until the disk is needed), and fully "
            "streamed from disk (budget 0; reported, not gated — the "
            "cost IS the verified disk round-trip: stage-in pread + "
            "per-row crc + stage-out write-back, which a smoke-sized "
            "model cannot hide behind compute).  stream_soak is a raw "
            "multi-GB SegmentStore write + crc-verified relay-window "
            "read pass."),
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)

    print("\n# Storage-tier A/B (l2l-p train step)")
    print("tiers,budget,prefetch,s_per_step,steps_per_s,"
          "read_MiB_per_step,compile_s")
    for r in results:
        tm = r.get("tier_metrics")
        rd = (tm["read_bytes"] / (1 << 20) / max(iters, 1)) if tm else 0.0
        b = r["host_budget_bytes"]
        tag = "-" if b is None else ("fits" if b else "0")
        print(f"{r['tiers']},{tag},{r['prefetch_depth']},"
              f"{r['s_per_step']:.4f},{r['steps_per_s']:.2f},{rd:.1f},"
              f"{r['compile_s']}")
    for k, v in sorted(slowdown.items()):
        print(f"# host-only/tier(fits) steps/s ({k}): {v:.3f}")
    for k, v in sorted(streamed.items()):
        print(f"# host-only/fully-streamed steps/s ({k}): {v:.3f}")
    print(f"# soak: {soak['streamed_gb']} GB, "
          f"write {soak['write_mb_s']} MB/s, "
          f"verified read {soak['verified_read_mb_s']} MB/s "
          f"(mmap {soak.get('mmap_read_mb_s', 'n/a')} MB/s, "
          f"pread {soak.get('pread_read_mb_s', 'n/a')} MB/s)")
    print(f"# wrote {out_path}")
    gate.ceiling_gate(slowdown, GATE, what="slowdown (fits arm)",
                      failure="storage tier regression: geomean "
                              "host-only/tier slowdown")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="smoke shapes, 2 prefetch points, 0.25 GB soak")
    ap.add_argument("--arch", default="bert-large")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ub", type=int, default=None)
    ap.add_argument("--gb", type=float, default=None,
                    help="soak size in GB (default 2.5, --tiny 0.25)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    return run(quick=args.tiny, arch=args.arch, steps=args.steps,
               batch=args.batch, seq=args.seq, ub=args.ub, gb=args.gb,
               out_path=args.out)


if __name__ == "__main__":
    main()
