"""End-to-end driver: train a ~100M-param BERT-family model with the full
L2L-p engine (eager per-layer Adam, microbatched, per-layer clip) on the
synthetic LM task for a few hundred steps.

    PYTHONPATH=src python examples/train_bert_l2l.py [--steps 300]

This is the deliverable-(b) end-to-end example; it reuses the production
driver (repro.launch.train) with a width override that lands at ~100M
parameters, and saves a checkpoint at the end.
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/l2l_bert_100m")
    args = ap.parse_args()

    from repro.launch.train import main as train_main
    # bert-large at d_model=576, 24 layers, vocab 30522:
    # ~ 2*30522*576 + 24*(4*576^2 + 2*576*2304) ≈ 107M params
    losses = train_main([
        "--arch", "bert-large", "--variant", "full",
        "--d-model", "576", "--n-layers", "24",
        "--engine", "l2l",
        "--steps", str(args.steps),
        "--batch", "32", "--seq", "128", "--ub", "4",
        "--lr", "3e-4", "--warmup", "50",
        "--clip", "1.0",
        "--ckpt-dir", args.ckpt_dir,
        "--log-every", "20",
    ])
    drop = losses[0] - sum(losses[-10:]) / 10
    print(f"loss drop over {args.steps} steps: {drop:.3f}")
    assert drop > 0.3, "expected the 100M model to learn the motifs"
    print(f"checkpoint in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
