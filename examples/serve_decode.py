"""Serving example: prefill + batched decode for four cache families —
full KV (granite), MLA-compressed (deepseek), O(1) recurrent state (rwkv),
enc-dec cross-attention (whisper) — plus the long-context ring-buffer mode,
all through the Engine facade's decode_init/decode_step.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro import engine as engines
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig


def demo(arch, window=0, gen=12):
    cfg = get_config(arch, "smoke")
    if window:
        cfg = cfg.replace(grouped_decode_attn=True)
    eng = engines.create("l2l", cfg, ExecutionConfig(decode_window=window))
    params = eng.model.init_params(jax.random.PRNGKey(0))
    B, P = (2, 8) if cfg.family == "audio" else (4, 16)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                cfg.vocab_size)
    frames = (jax.random.normal(jax.random.PRNGKey(9),
                                (B, cfg.n_frames, cfg.d_model), jnp.bfloat16)
              if cfg.family == "audio" else None)
    live = window if window else P + gen
    t0 = time.time()
    caches, logits = eng.decode_init(params, prompt, live, frames=frames)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    toks = [tok]
    for i in range(gen - 1):
        logits, caches = eng.decode_step(params, caches, tok,
                                         jnp.int32(P + i))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks.append(tok)
    out = jnp.concatenate(toks, 1)
    dt = time.time() - t0
    mode = (f"ring window={window}" if window
            else "enc-dec cross-attn" if cfg.family == "audio"
            else "O(1) state" if cfg.family == "ssm"
            else "MLA compressed" if cfg.use_mla
            else f"full cache={live}")
    print(f"{arch:24s} [{mode:20s}] generated {tuple(out.shape)} "
          f"in {dt:5.1f}s  sample={out[0, :8].tolist()}")
    return out


def main():
    demo("granite-3-8b")                 # dense GQA, full KV cache
    demo("deepseek-v2-lite-16b")         # MLA compressed cache (absorbed)
    demo("rwkv6-1.6b")                   # attention-free recurrent state
    demo("whisper-base")                 # enc-dec with cross-attn cache
    demo("granite-3-8b", window=8)       # long-context ring buffer


if __name__ == "__main__":
    main()
