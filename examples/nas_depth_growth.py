"""Dynamic depth growth — the paper's NAS enablement claim.

"L2L scales to arbitrary depth without impacting memory or devices …
It also enables dynamic approaches such as neural architecture search."

Because the L2L engine executes a *stacked* layer axis (and the device
only ever holds one layer), growing the network mid-training is just
concatenating freshly-initialized layers (+ zero optimizer slots) onto
the stacked pytrees in the TrainState — a new Engine for the deeper
config picks the state up unchanged; no device-footprint change.

    PYTHONPATH=src python examples/nas_depth_growth.py
"""
import jax
import jax.numpy as jnp

from repro import engine as engines
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models.common import materialize, stack_specs
from repro.optim import adam


def grow(eng, state, extra_layers, rng, opt):
    """Append freshly-initialized layers to group 0 (identity-friendly:
    new blocks start with near-zero residual contributions).  Returns the
    deeper engine and the carried-over TrainState."""
    cfg = eng.model.cfg.replace(
        n_layers=eng.model.cfg.n_layers + extra_layers)
    new_eng = engines.create(eng.name, cfg, eng.exec_cfg, optimizer=opt,
                             donate=False)
    fresh = materialize(stack_specs(eng.model.groups[0].spec, extra_layers),
                        rng)
    # scale down the fresh layers' output projections so growth is smooth
    fresh = jax.tree.map(lambda a: a * 0.1, fresh)
    cat = lambda old, new: jax.tree.map(
        lambda a, b: jnp.concatenate([a, b.astype(a.dtype)], 0), old, new)
    params = dict(state.params)
    params["groups"] = (cat(params["groups"][0], fresh),)
    opt_state = dict(state.opt_state)
    opt_state["groups"] = (cat(opt_state["groups"][0], opt.init(fresh)),)
    return new_eng, state.replace(params=params, opt_state=opt_state)


def run_phase(eng, state, data, start, steps):
    losses = []
    for i in range(start, start + steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, m = eng.train_step(state, b)
        losses.append(float(m["loss"]))
    return state, losses


def main():
    cfg = get_config("bert-large", "smoke")
    opt = adam(lr=1e-3)
    eng = engines.create("l2l-p", cfg, ExecutionConfig(n_microbatches=2),
                         optimizer=opt, donate=False)
    state = eng.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=8))

    state, l1 = run_phase(eng, state, data, 0, 25)
    print(f"phase 1 (depth {eng.model.cfg.n_layers}): "
          f"loss {l1[0]:.3f} -> {l1[-1]:.3f}")

    eng, state = grow(eng, state, 2, jax.random.PRNGKey(42), opt)
    state, l2 = run_phase(eng, state, data, 25, 25)
    print(f"phase 2 (depth {eng.model.cfg.n_layers}): "
          f"loss {l2[0]:.3f} -> {l2[-1]:.3f}")
    assert l2[-1] < l1[0], "grown model must keep improving"
    assert abs(l2[0] - l1[-1]) < 0.5, "growth must not reset learning"
    print("depth grew 2 -> 4 mid-training; device-resident footprint "
          "unchanged (one layer at a time, regardless of N)")


if __name__ == "__main__":
    main()
