"""Dynamic depth growth — the paper's NAS enablement claim, zero-recompile.

"L2L scales to arbitrary depth without impacting memory or devices …
It also enables dynamic approaches such as neural architecture search."

Because the L2L engine executes a *stacked* layer axis (and the device
only ever holds one layer), growing the network mid-training is cheap at
the MEMORY level — but rebuilding the engine per depth still paid a full
re-jit per growth step.  ``ExecutionConfig.dynamic_depth`` removes that
too: the jitted step takes depth as a traced ``n_layers`` operand, so
ONE engine at the capacity depth serves every growth stage from the same
compiled program.  Layers past the runtime depth pass activations
through untouched and keep their params/optimizer rows bit-frozen — the
state IS the capacity state from step 0, growth just raises the bound.

    PYTHONPATH=src python examples/nas_depth_growth.py
"""
import jax
import jax.numpy as jnp

from repro import engine as engines
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.optim import adam

CAPACITY = 8
START_DEPTH = 2
GROW_BY = 2          # 2 -> 4 -> 6 -> 8: three growth iterations


def run_phase(eng, state, data, start, steps, depth):
    losses = []
    for i in range(start, start + steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, m = eng.train_step(state, b, depth)
        losses.append(float(m["loss"]))
    return state, losses


def main():
    cfg = get_config("bert-large", "smoke").replace(n_layers=CAPACITY)
    opt = adam(lr=1e-3)
    eng = engines.create("l2l-p", cfg,
                         ExecutionConfig(n_microbatches=2,
                                         dynamic_depth=True),
                         optimizer=opt, donate=False)
    state = eng.init(jax.random.PRNGKey(0))
    # scale down the dormant tail layers' weights so each growth step
    # starts from near-zero residual contributions (smooth growth) —
    # they sit bit-frozen until the runtime depth reaches them
    params = dict(state.params)
    params["groups"] = tuple(
        jax.tree.map(lambda a: a.at[START_DEPTH:].mul(0.1), g)
        for g in params["groups"])
    state = state.replace(params=params)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=8))

    depth, step, prev = START_DEPTH, 0, None
    while depth <= CAPACITY:
        state, ls = run_phase(eng, state, data, step, 25, depth)
        step += 25
        compiles = eng._fns["train_step"]._cache_size()
        print(f"depth {depth} (capacity {CAPACITY}): "
              f"loss {ls[0]:.3f} -> {ls[-1]:.3f}   "
              f"[compiled programs: {compiles}]")
        if prev is not None:
            assert abs(ls[0] - prev[-1]) < 0.5, \
                "growth must not reset learning"
        prev = ls
        depth += GROW_BY

    compiles = eng._fns["train_step"]._cache_size()
    assert compiles == 1, f"expected ONE compile, saw {compiles}"
    n_growth = (CAPACITY - START_DEPTH) // GROW_BY
    print(f"\ndepth grew {START_DEPTH} -> {CAPACITY} across {n_growth} "
          f"growth iterations under EXACTLY ONE compiled program "
          f"(jit cache size {compiles}); device-resident footprint "
          f"unchanged (one layer at a time, regardless of N)")


if __name__ == "__main__":
    main()
