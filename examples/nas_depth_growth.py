"""Dynamic depth growth — the paper's NAS enablement claim.

"L2L scales to arbitrary depth without impacting memory or devices …
It also enables dynamic approaches such as neural architecture search."

Because the L2L engine executes a *stacked* layer axis (and the device
only ever holds one layer), growing the network mid-training is just
concatenating freshly-initialized layers (+ zero optializer slots) onto
the stacked pytrees — no engine change, no device-footprint change.

    PYTHONPATH=src python examples/nas_depth_growth.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import l2l
from repro.core.schedule import ExecutionConfig
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models.common import materialize
from repro.models.model import LayeredModel
from repro.optim import adam


def grow(model, params, opt_state, extra_layers, rng):
    """Append freshly-initialized layers to group 0 (identity-friendly:
    new blocks start with near-zero residual contributions)."""
    cfg = model.cfg.replace(n_layers=model.cfg.n_layers + extra_layers)
    new_model = LayeredModel(cfg)
    fresh = materialize(
        __import__("repro.models.common", fromlist=["stack_specs"]
                   ).stack_specs(model.groups[0].spec, extra_layers),
        rng)
    # scale down the fresh layers' output projections so growth is smooth
    def dampen(tree):
        return jax.tree.map(lambda a: a * 0.1, tree)
    fresh = dampen(fresh)
    cat = lambda old, new: jax.tree.map(
        lambda a, b: jnp.concatenate([a, b.astype(a.dtype)], 0), old, new)
    params = dict(params)
    params["groups"] = (cat(params["groups"][0], fresh),)
    opt = adam(lr=1e-3)
    fresh_opt = opt.init(fresh)
    opt_state = dict(opt_state)
    opt_state["groups"] = (cat(opt_state["groups"][0], fresh_opt),)
    return new_model, params, opt_state


def run_phase(model, params, opt_state, data, start, steps, opt):
    step = jax.jit(l2l.make_train_step(model, opt,
                                       ExecutionConfig(n_microbatches=2)))
    losses = []
    for i in range(start, start + steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt_state, m = step(params, opt_state, b)
        losses.append(float(m["loss"]))
    return params, opt_state, losses


def main():
    cfg = get_config("bert-large", "smoke")
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adam(lr=1e-3)
    opt_state = l2l.init_opt_state(opt, params)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=8))

    params, opt_state, l1 = run_phase(model, params, opt_state, data, 0,
                                      25, opt)
    print(f"phase 1 (depth {model.cfg.n_layers}): "
          f"loss {l1[0]:.3f} -> {l1[-1]:.3f}")

    model, params, opt_state = grow(model, params, opt_state, 2,
                                    jax.random.PRNGKey(42))
    params, opt_state, l2 = run_phase(model, params, opt_state, data, 25,
                                      25, opt)
    print(f"phase 2 (depth {model.cfg.n_layers}): "
          f"loss {l2[0]:.3f} -> {l2[-1]:.3f}")
    assert l2[-1] < l1[0], "grown model must keep improving"
    assert abs(l2[0] - l1[-1]) < 0.5, "growth must not reset learning"
    print("depth grew 2 -> 4 mid-training; device-resident footprint "
          "unchanged (one layer at a time, regardless of N)")


if __name__ == "__main__":
    main()
