"""Quickstart: the Engine facade in ~10 lines.

Every execution schedule in the repo is an engine behind one registry —
``engines.create(name, model_cfg, exec_cfg)`` — with the same lifecycle:
``init`` -> ``train_step`` -> ``prefill``.  This builds a small dense LM,
runs the SAME step through all three schedules and shows the gradients
are numerically identical (the paper's core claim), then prints the
analytic two-tier memory split (eqs. 1-4) for the full-size model.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import engine as engines
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig
from repro.data.synthetic import DataConfig, SyntheticLM


def main():
    cfg = get_config("bert-large", "smoke").replace(dtype="float32")
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    ec = ExecutionConfig(n_microbatches=2)

    # --- the 10-line engine lifecycle ---------------------------------
    eng = engines.create("l2l-p", cfg, ec)          # Alg 4 (L2L-p)
    state = eng.init(jax.random.PRNGKey(0))         # params + opt TrainState
    state, metrics = eng.train_step(state, batch)   # one update (jitted)
    logits = eng.prefill(state, batch)              # forward relay
    print(f"train_step: loss={float(metrics['loss']):.4f} "
          f"step={int(state.step)}  prefill logits {tuple(logits.shape)}")

    # --- gradient identity across every registered schedule -----------
    params = engines.create("baseline", cfg, ec).init(
        jax.random.PRNGKey(0)).params
    grads = {name: engines.create(name, cfg, ec).grads(params, batch)
             for name in engines.available()}
    loss_ref, g_ref = grads["baseline"]
    for name, (loss, g) in grads.items():
        err = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), g_ref, g)))
        print(f"loss[{name:9s}] = {float(loss):.6f}   "
              f"max |grad diff| vs baseline = {err:.2e}")
    print("-> identical math, inverted loops.")

    # Where the memory went: full-size BERT-large, batch 32, seq 512
    full = get_config("bert-large", "full")
    for name in ("baseline", "l2l", "l2l-p"):
        eng = engines.create(
            name, full, ExecutionConfig(n_microbatches=8,
                                        offload_stash=(name == "l2l-p")))
        r = eng.memory_estimate(batch=32, seq=512)
        print(f"{name:9s} device={r.total_device/2**30:6.2f} GiB   "
              f"host(EPS)={r.total_host/2**30:6.2f} GiB")
    print("-> the paper's Table 2 story: the device footprint stops "
          "depending on depth.")

    # --- constant-memory stash: stash_every=K checkpoints every K-th
    # boundary (ceil(N/K) stashed) and recomputes the rest during the
    # reverse relay — the stash stops growing with depth too ------------
    for K in (1, 8):
        eng = engines.create("l2l-p", full, ExecutionConfig(
            n_microbatches=8, offload_stash=True, stash_every=K))
        r = eng.memory_estimate(batch=32, seq=512)
        print(f"l2l-p stash_every={K}: stash={r.stash/2**20:7.1f} MiB "
              f"({r.stash_boundaries} boundaries), "
              f"recompute={r.recompute_layers} extra layer-fwd/step")
    # the grads are bit-identical — reuse the identity section's l2l-p
    # grads (stash_every=1) and params, compute only the K=4 side
    eK = engines.create("l2l-p", cfg, ec,
                        exec_overrides={"stash_every": 4})
    _, gK = eK.grads(params, batch)
    same = all(bool(jnp.all(a == b)) for a, b in
               zip(jax.tree.leaves(grads["l2l-p"][1]), jax.tree.leaves(gK)))
    print(f"-> stash_every=4 grads bit-identical to stash_every=1: {same}")


if __name__ == "__main__":
    main()
