"""Quickstart: the L2L execution schedule in ~60 lines.

Builds a small dense LM, runs ONE training step three ways and shows they
are numerically identical — the paper's core claim — then prints the
analytic two-tier memory split (eqs. 1-4) for the full-size model.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import baseline, l2l
from repro.core.memory_model import estimate
from repro.core.schedule import ExecutionConfig
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models.model import LayeredModel


def main():
    cfg = get_config("bert-large", "smoke").replace(dtype="float32")
    model = LayeredModel(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}

    # Algorithm 1/2: conventional execution (microbatch loop inner)
    loss_a2, g_a2 = jax.jit(baseline.make_grads_fn(
        model, ExecutionConfig(n_microbatches=2)))(params, batch)
    # Algorithm 3: L2L — LAYER loop outer, microbatch loop inner,
    # per-layer recompute from the boundary stash
    loss_l2l, g_l2l = jax.jit(l2l.make_grads_fn(
        model, ExecutionConfig(n_microbatches=2)))(params, batch)

    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g_a2, g_l2l)))
    print(f"loss baseline-AG = {float(loss_a2):.6f}")
    print(f"loss L2L         = {float(loss_l2l):.6f}")
    print(f"max |grad diff|  = {err:.2e}   (identical math, inverted loops)")

    # Where the memory went: full-size BERT-large, batch 32, seq 512
    full = LayeredModel(get_config("bert-large", "full"))
    for mode in ("baseline", "l2l", "l2l_p"):
        r = estimate(full, batch=32, seq=512, n_microbatches=8, mode=mode,
                     offload_stash=(mode == "l2l_p"))
        print(f"{mode:9s} device={r.total_device/2**30:6.2f} GiB   "
              f"host(EPS)={r.total_host/2**30:6.2f} GiB")
    print("-> the paper's Table 2 story: the device footprint stops "
          "depending on depth.")


if __name__ == "__main__":
    main()
