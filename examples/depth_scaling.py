"""The paper's headline demo, reproduced: scale DEPTH at fixed device
budget.  BERT at 12/24/48/96 layers — the baseline's device working set
grows linearly and falls over; L2L's stays flat (Table 2: a 96-layer BERT
in 11.13 GB where baseline OOMs at 48).

Compile-only on this container (memory_analysis, nothing allocated), plus
the analytic eq. (1)-(4) split via each engine's ``memory_estimate``.
The second half runs a LIVE depth sweep at smoke scale under
``dynamic_depth``: one compiled program serves every depth — the sweep
that used to pay one jit per point pays exactly one total.

    PYTHONPATH=src python examples/depth_scaling.py
"""
import jax

from repro import engine as engines
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig

V100_GB = 16.0


def main():
    print(f"{'layers':>7} {'baseline dev (GiB)':>20} {'L2L dev (GiB)':>15} "
          f"{'L2L host/EPS (GiB)':>20}  verdict")
    for n in (12, 24, 48, 96):
        cfg = get_config("bert-large", "full").replace(n_layers=n)
        base = engines.create("baseline", cfg)
        l2lp = engines.create("l2l-p", cfg, ExecutionConfig(
            n_microbatches=8, offload_stash=True))
        b = base.memory_estimate(batch=32, seq=512)
        l = l2lp.memory_estimate(batch=32, seq=512)
        base_dev = (b.total_device + b.opt_state) / 2**30
        l2l_dev = l.total_device / 2**30
        l2l_host = l.total_host / 2**30
        verdict = ("OOM on a 16GB device" if base_dev > V100_GB else "fits")
        print(f"{n:7d} {base_dev:20.2f} {l2l_dev:15.2f} {l2l_host:20.2f}"
              f"  baseline {verdict}; L2L fits")
    print("\npaper Table 2: baseline OOM at 48L; L2L runs 96L in 11.13 GB.")
    print("L2L device bytes are DEPTH-INDEPENDENT (eq. 4) — the stash and "
          "the model live in the EPS.")


def dynamic_sweep():
    """Sweep runtime depths under ONE compiled program."""
    import jax.numpy as jnp

    from repro.data.synthetic import DataConfig, SyntheticLM
    from repro.optim import adam
    CAP = 12
    cfg = get_config("bert-large", "smoke").replace(n_layers=CAP,
                                                    dtype="float32")
    eng = engines.create("l2l-p", cfg,
                         ExecutionConfig(n_microbatches=2,
                                         stash_every=2,
                                         dynamic_depth=True),
                         optimizer=adam(), donate=False)
    params = eng.model.init_params(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=4))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    print(f"\nlive depth sweep at smoke scale (capacity {CAP}, "
          f"dynamic_depth):")
    for n in (3, 6, 12):
        loss, _ = eng.grads(params, batch, n)
        print(f"  depth {n:3d}: loss {float(loss):.3f}   "
              f"[compiled programs: {eng._fns['grads']._cache_size()}]")
    assert eng._fns["grads"]._cache_size() == 1
    print("one compile served the whole sweep (jit cache size 1)")


if __name__ == "__main__":
    main()
    dynamic_sweep()
