"""Synthetic LM data pipeline.

Deterministic, seekable token streams (no external datasets in this
container): a mixture of (a) a Zipf-distributed unigram stream, (b) short
repeated n-gram motifs (so a model can actually LEARN something — the
convergence benchmarks need a learnable signal), and (c) a tiny fraction of
uniform noise.  Documents are delimited and packed into fixed-length
sequences with next-token targets, mirroring a production LM pipeline
(tokenize -> pack -> shard by host).

Everything is a pure function of (seed, index) so any host in a multi-pod
job can materialize exactly its shard without coordination.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    motif_len: int = 8
    n_motifs: int = 64
    motif_frac: float = 0.7        # fraction of tokens from repeated motifs
    pad_id: int = 0
    # host sharding
    host_index: int = 0
    host_count: int = 1


class SyntheticLM:
    """Seekable synthetic token source + packer."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # fixed motif bank (learnable structure)
        self.motifs = root.integers(1, v, size=(cfg.n_motifs, cfg.motif_len),
                                    dtype=np.int64)
        # Zipf-ish unigram distribution over the vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.unigram = p / p.sum()

    def _doc(self, rng: np.random.Generator, min_len=64, max_len=512):
        n = int(rng.integers(min_len, max_len))
        out = []
        while len(out) < n:
            if rng.random() < self.cfg.motif_frac:
                m = self.motifs[int(rng.integers(0, self.cfg.n_motifs))]
                out.extend(m.tolist())
            else:
                out.append(int(rng.choice(self.cfg.vocab_size,
                                          p=self.unigram)))
        return out[:n]

    def batch(self, step: int) -> dict:
        """Deterministic global batch for ``step`` — this host's shard."""
        cfg = self.cfg
        assert cfg.global_batch % cfg.host_count == 0
        per_host = cfg.global_batch // cfg.host_count
        B, S = per_host, cfg.seq_len
        toks = np.zeros((B, S + 1), np.int64)
        for b in range(B):
            # unique, seekable stream per (step, global row)
            row = cfg.host_index * per_host + b
            rng = np.random.default_rng(
                (cfg.seed, step, row))
            buf: list = []
            while len(buf) < S + 1:
                buf.extend(self._doc(rng))
                buf.append(cfg.pad_id)        # doc delimiter
            toks[b] = buf[:S + 1]
        tokens = toks[:, :-1].astype(np.int32)
        targets = toks[:, 1:].astype(np.int32)
        mask = (targets != cfg.pad_id).astype(np.float32)
        return {"tokens": tokens, "targets": targets, "mask": mask}

    def batches(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


def add_modality_stubs(batch: dict, cfg, rng: Optional[np.random.Generator]
                       = None) -> dict:
    """Attach the stubbed frontend embeddings the assignment carves out
    (audio frames / vision patches) as deterministic pseudo features."""
    rng = rng or np.random.default_rng(1234)
    B = batch["tokens"].shape[0]
    if cfg.family == "audio":
        batch = dict(batch, frames=rng.standard_normal(
            (B, cfg.n_frames, cfg.d_model)).astype(np.float32))
    if cfg.is_vlm:
        batch = dict(batch, patches=rng.standard_normal(
            (B, cfg.n_patches, cfg.vit_dim)).astype(np.float32))
    return batch
