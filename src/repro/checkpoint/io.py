"""Crash-consistent checkpointing: pytree <-> snapshot directory.

No orbax in this container, so this is a small but complete
implementation with the durability contract a preemptible long run
needs (the paper's setting — one cheap device, days of training):

* **One snapshot = one directory** (``ckpt_<step>/``) holding
  ``arrays.npz`` (every leaf, flattened with
  ``tree_flatten_with_path``) and ``manifest.json`` (key paths, dtypes,
  shapes, a crc32 per stored array, the step, and an optional caller
  fingerprint binding the snapshot to a model/optimizer layout).
* **Write-to-temp + fsync + atomic rename**: the snapshot is staged in
  a dot-prefixed temp directory next to its final name, every file is
  fsynced, the directory is renamed into place in one atomic step, and
  the parent directory is fsynced so the rename itself is durable.  A
  crash at ANY point leaves either the previous snapshots untouched
  plus an ignorable ``.tmp-*`` directory, or the complete new snapshot
  — never a half-written one under the real name.
* **Verification**: ``verify()`` recomputes a whole-file crc32 of
  ``arrays.npz``, every array's crc32, and the manifest's self-checksum
  against the manifest (so ANY flipped or truncated byte in either
  file is caught — container metadata included), plus the fingerprint;
  ``restore()`` verifies by default before deserializing anything into
  the training state.
* **Discovery**: ``latest_good()`` walks snapshots newest-first and
  returns the first one that verifies, so a corrupt or partial newest
  snapshot silently falls back to the previous good one.
* **Retention**: ``save_train_state(..., keep_last=N)`` prunes the
  oldest snapshots after a successful save (temp debris included).

Layout stability: checkpoints are ALWAYS the unpacked per-leaf pytree.
Engines running the packed relay (``ExecutionConfig.pack_params``)
convert their flat buffers through ``repro.core.packing``'s PackSpec
converters in ``Engine.save``/``restore``, so a checkpoint written with
packing on restores with packing off and vice versa
(tests/test_packing.py).
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, List, Optional

import jax
import numpy as np

ARRAYS = "arrays.npz"
MANIFEST = "manifest.json"
_TMP = ".tmp-"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


_WIDE = {2: np.uint16, 1: np.uint8, 4: np.uint32}


def _manifest_crc(manifest: dict) -> int:
    """Self-checksum over every manifest field except the checksum
    itself (canonical serialization, so load-recompute matches
    save-compute bit-for-bit)."""
    payload = {k: v for k, v in manifest.items() if k != "manifest_crc32"}
    return zlib.crc32(json.dumps(payload, sort_keys=True).encode())


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    # directory fsync makes the rename (the commit point) durable; some
    # filesystems refuse O_RDONLY dir fsync — best-effort there
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def save(path: str, tree: Any, step: Optional[int] = None,
         fingerprint: Optional[str] = None) -> str:
    """Atomically write ``tree`` as the snapshot directory ``path``.

    The snapshot is staged under a temp name in the same parent and
    renamed into place only after every byte (arrays, manifest) is
    fsynced — a crash mid-save can never leave a half-written snapshot
    under the final name.  Returns ``path``."""
    path = path.rstrip("/")
    leaves_with_paths, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    manifest = {"version": 2, "keys": [], "dtypes": [], "shapes": [],
                "crc32": [], "step": step, "fingerprint": fingerprint}
    for i, (kp, leaf) in enumerate(leaves_with_paths):
        arr = np.asarray(jax.device_get(leaf))
        manifest["dtypes"].append(str(arr.dtype))
        manifest["shapes"].append(list(arr.shape))
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # numpy can't round-trip ml_dtypes (bfloat16 etc): store raw bits
            arr = arr.view(_WIDE[arr.dtype.itemsize])
        arrays[f"a{i}"] = arr
        manifest["crc32"].append(
            zlib.crc32(np.ascontiguousarray(arr).tobytes()))
        manifest["keys"].append(_path_str(kp))

    parent = os.path.dirname(path) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = os.path.join(parent, _TMP + os.path.basename(path) +
                       f".{os.getpid()}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    try:
        np.savez_compressed(os.path.join(tmp, ARRAYS), **arrays)
        _fsync_file(os.path.join(tmp, ARRAYS))
        # whole-file crc: per-array checksums can't see damage to the
        # npz container's own metadata bytes — this can
        with open(os.path.join(tmp, ARRAYS), "rb") as f:
            manifest["file_crc32"] = zlib.crc32(f.read())
        manifest["manifest_crc32"] = _manifest_crc(manifest)
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        _fsync_dir(tmp)
        if os.path.exists(path):        # overwrite = replace atomically too
            shutil.rmtree(path)
        os.rename(tmp, path)            # the commit point
        _fsync_dir(parent)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return path


def read_manifest(path: str) -> Optional[dict]:
    """The snapshot's manifest dict, or None when absent/unparseable."""
    try:
        with open(os.path.join(path, MANIFEST)) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


# Memoization of the HEAVY byte-verification pass: the tier store's
# quarantine-rebuild, restore-time discovery and the relay's periodic
# latest_good() probes all re-verify the same unchanged snapshots.  The
# cache key binds the verdict to BOTH files' (mtime_ns, size) — the
# manifest alone is not enough: in-place damage to arrays.npz (disk rot,
# chaos-suite bitflips) leaves the manifest untouched, so any key that
# ignored the arrays file would keep vouching for rotten bytes.  Cheap
# structural checks (manifest parse/self-crc, fingerprint) are NOT
# cached — the fingerprint varies per caller.
_VERIFY_CACHE: dict = {}
_VERIFY_CACHE_MAX = 256


def _verify_cache_key(path: str):
    try:
        man = os.stat(os.path.join(path, MANIFEST))
        arr = os.stat(os.path.join(path, ARRAYS))
    except OSError:
        return None
    return (os.path.abspath(path), man.st_mtime_ns, man.st_size,
            arr.st_mtime_ns, arr.st_size)


def _verify_bytes(path: str, manifest: dict) -> bool:
    """The byte-level pass: whole-file crc32 of arrays.npz + every
    array's shape and crc32 against the manifest.  Split out (and
    memoized by ``verify``) so tests can count/monkeypatch the heavy
    reads independently of the cheap structural checks."""
    try:
        with open(os.path.join(path, ARRAYS), "rb") as f:
            if zlib.crc32(f.read()) != manifest.get("file_crc32"):
                return False
        with np.load(os.path.join(path, ARRAYS)) as data:
            if len(data.files) != len(manifest["keys"]):
                return False
            for i, (crc, shape) in enumerate(zip(manifest["crc32"],
                                                 manifest["shapes"])):
                arr = data[f"a{i}"]
                if list(arr.shape) != list(shape):
                    return False
                if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != crc:
                    return False
    except Exception:
        # truncated zip / flipped bits in the compressed stream / missing
        # file all surface as read errors — corrupt either way
        return False
    return True


def verify(path: str, fingerprint: Optional[str] = None) -> bool:
    """True iff the snapshot at ``path`` is complete and uncorrupted:
    manifest present and parseable, every array readable with its
    recorded shape, every crc32 matching the stored bytes, and (when
    both sides carry one) the fingerprint matching the caller's.  The
    byte pass is memoized by (path, manifest + arrays mtime_ns/size), so
    repeated probes of an unchanged snapshot cost two stat() calls."""
    manifest = read_manifest(path)
    if manifest is None or "crc32" not in manifest:
        return False
    if manifest.get("manifest_crc32") != _manifest_crc(manifest):
        return False                    # the manifest itself is damaged
    if (fingerprint is not None
            and manifest.get("fingerprint") is not None
            and manifest["fingerprint"] != fingerprint):
        return False
    key = _verify_cache_key(path)
    if key is not None and key in _VERIFY_CACHE:
        return _VERIFY_CACHE[key]
    ok = _verify_bytes(path, manifest)
    if key is not None:
        if len(_VERIFY_CACHE) >= _VERIFY_CACHE_MAX:
            _VERIFY_CACHE.clear()
        _VERIFY_CACHE[key] = ok
    return ok


def restore(path: str, like: Any, shardings: Any = None,
            check: bool = True, fingerprint: Optional[str] = None) -> Any:
    """Restore into the structure of ``like`` (arrays or
    ShapeDtypeStructs), verifying checksums first (``check=False`` skips
    the integrity pass for callers that already ran ``verify``).  If
    ``shardings`` is given (same structure), device_put accordingly."""
    if check:
        assert verify(path, fingerprint=fingerprint), \
            f"checkpoint {path} failed integrity verification " \
            f"(truncated, bit-flipped, or fingerprint mismatch)"
    manifest = read_manifest(path)
    assert manifest is not None, f"no manifest in {path}"
    data = np.load(os.path.join(path, ARRAYS))
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    assert len(leaves_with_paths) == len(manifest["keys"]), \
        f"checkpoint has {len(manifest['keys'])} leaves, " \
        f"structure needs {len(leaves_with_paths)}"
    out = []
    for i, (kp, ref) in enumerate(leaves_with_paths):
        key = _path_str(kp)
        assert manifest["keys"][i] == key, \
            f"leaf order mismatch: {manifest['keys'][i]} vs {key}"
        arr = data[f"a{i}"]
        saved_dt = manifest["dtypes"][i]
        if saved_dt and arr.dtype.kind == "u" and saved_dt not in (
                "uint8", "uint16", "uint32", "uint64"):
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, saved_dt, saved_dt)))
        assert tuple(arr.shape) == tuple(ref.shape), \
            f"{key}: shape {arr.shape} vs {ref.shape}"
        out.append(arr.astype(ref.dtype))
    restored = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        restored = jax.tree.map(jax.device_put, restored, shardings)
    return restored


# ---------------------------------------------------------------------------
# Snapshot discovery / retention over a checkpoint directory
# ---------------------------------------------------------------------------
def _snapshot_steps(directory: str, prefix: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for f in os.listdir(directory):
        if f.startswith(prefix + "_") and \
                os.path.isdir(os.path.join(directory, f)):
            try:
                steps.append(int(f[len(prefix) + 1:]))
            except ValueError:
                pass
    return sorted(steps)


def snapshot_path(directory: str, step: int, prefix: str = "ckpt") -> str:
    return os.path.join(directory, f"{prefix}_{step}")


def latest_step(directory: str, prefix: str = "ckpt") -> Optional[int]:
    """Newest snapshot by step number (existence only — see
    ``latest_good`` for the verified variant)."""
    steps = _snapshot_steps(directory, prefix)
    return steps[-1] if steps else None


def latest_good(directory: str, prefix: str = "ckpt",
                fingerprint: Optional[str] = None) -> Optional[int]:
    """Newest snapshot that passes ``verify()`` — a truncated or
    bit-flipped newest snapshot (e.g. preempted mid-write on a
    filesystem without atomic rename, or disk rot) is skipped and the
    previous good one wins.  None when no good snapshot exists."""
    for step in reversed(_snapshot_steps(directory, prefix)):
        if verify(snapshot_path(directory, step, prefix),
                  fingerprint=fingerprint):
            return step
    return None


def prune(directory: str, keep_last: int, prefix: str = "ckpt") -> List[int]:
    """Delete all but the newest ``keep_last`` snapshots (plus any
    leftover ``.tmp-*`` staging debris from crashed saves); returns the
    pruned step numbers.  ``keep_last <= 0`` disables pruning (debris is
    still swept)."""
    removed = []
    if os.path.isdir(directory):
        for f in os.listdir(directory):
            if f.startswith(_TMP):
                shutil.rmtree(os.path.join(directory, f),
                              ignore_errors=True)
    if keep_last <= 0:
        return removed
    steps = _snapshot_steps(directory, prefix)
    for step in steps[:-keep_last]:
        shutil.rmtree(snapshot_path(directory, step, prefix),
                      ignore_errors=True)
        removed.append(step)
    return removed


# ---------------------------------------------------------------------------
# Train-state convenience wrappers (what Engine.save/restore call)
# ---------------------------------------------------------------------------
def save_train_state(directory: str, params, opt_state, step: int,
                     prefix: str = "ckpt", keep_last: int = 0,
                     fingerprint: Optional[str] = None) -> str:
    path = save(snapshot_path(directory, step, prefix),
                {"params": params, "opt": opt_state}, step=step,
                fingerprint=fingerprint)
    prune(directory, keep_last, prefix)
    return path


def restore_train_state(directory: str, params_like, opt_like,
                        step: Optional[int] = None, prefix: str = "ckpt",
                        fingerprint: Optional[str] = None):
    """Restore the newest GOOD snapshot (or the requested step).  A
    corrupt newest snapshot is skipped by ``latest_good`` — restore
    falls back to the previous verified one rather than loading
    garbage."""
    if step is None:
        step = latest_good(directory, prefix, fingerprint=fingerprint)
    assert step is not None, \
        f"no verifiable checkpoint in {directory} (prefix={prefix})"
    path = snapshot_path(directory, step, prefix)
    tree = restore(path, {"params": params_like, "opt": opt_like},
                   fingerprint=fingerprint)
    return tree["params"], tree["opt"], step
