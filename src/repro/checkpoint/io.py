"""Checkpointing: pytree <-> .npz with structure manifest.

No orbax in this container, so this is a small but complete implementation:
flattens any params/opt pytree with ``jax.tree_util.tree_flatten_with_path``,
saves leaves into one compressed npz plus a JSON manifest of key-paths and
dtypes, and restores into the exact structure (verifying shapes/dtypes).
Device arrays are gathered to host before save; restore optionally
device_puts onto provided shardings (so a multi-pod job can restore straight
into its EPS placement).

Layout stability: checkpoints are ALWAYS the unpacked per-leaf pytree.
Engines running the packed relay (``ExecutionConfig.pack_params``) convert
their flat buffers through ``repro.core.packing``'s PackSpec converters in
``Engine.save``/``restore``, so a checkpoint written with packing on
restores with packing off and vice versa (tests/test_packing.py).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


_WIDE = {2: np.uint16, 1: np.uint8, 4: np.uint32}


def save(path: str, tree: Any, step: Optional[int] = None) -> None:
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    manifest = {"keys": [], "dtypes": [], "step": step}
    for i, (kp, leaf) in enumerate(leaves_with_paths):
        key = f"a{i}"
        arr = np.asarray(jax.device_get(leaf))
        manifest["dtypes"].append(str(arr.dtype))
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # numpy can't round-trip ml_dtypes (bfloat16 etc): store raw bits
            arr = arr.view(_WIDE[arr.dtype.itemsize])
        arrays[key] = arr
        manifest["keys"].append(_path_str(kp))
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez_compressed(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        json.dump(manifest, f)


def restore(path: str, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (arrays or ShapeDtypeStructs).
    If ``shardings`` is given (same structure), device_put accordingly."""
    with open(path + ".json") as f:
        manifest = json.load(f)
    data = np.load(path + ".npz")
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    assert len(leaves_with_paths) == len(manifest["keys"]), \
        f"checkpoint has {len(manifest['keys'])} leaves, " \
        f"structure needs {len(leaves_with_paths)}"
    out = []
    for i, (kp, ref) in enumerate(leaves_with_paths):
        key = _path_str(kp)
        assert manifest["keys"][i] == key, \
            f"leaf order mismatch: {manifest['keys'][i]} vs {key}"
        arr = data[f"a{i}"]
        saved_dt = manifest.get("dtypes", [None] * len(manifest["keys"]))[i]
        if saved_dt and arr.dtype.kind == "u" and saved_dt not in (
                "uint8", "uint16", "uint32", "uint64"):
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, saved_dt, saved_dt)))
        assert tuple(arr.shape) == tuple(ref.shape), \
            f"{key}: shape {arr.shape} vs {ref.shape}"
        out.append(arr.astype(ref.dtype))
    restored = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        restored = jax.tree.map(jax.device_put, restored, shardings)
    return restored


def latest_step(directory: str, prefix: str = "ckpt") -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for f in os.listdir(directory):
        if f.startswith(prefix + "_") and f.endswith(".json"):
            try:
                steps.append(int(f[len(prefix) + 1:-5]))
            except ValueError:
                pass
    return max(steps) if steps else None


def save_train_state(directory: str, params, opt_state, step: int,
                     prefix: str = "ckpt") -> str:
    path = os.path.join(directory, f"{prefix}_{step}")
    save(path, {"params": params, "opt": opt_state}, step=step)
    return path


def restore_train_state(directory: str, params_like, opt_like,
                        step: Optional[int] = None, prefix: str = "ckpt"):
    step = step if step is not None else latest_step(directory, prefix)
    assert step is not None, f"no checkpoint in {directory}"
    path = os.path.join(directory, f"{prefix}_{step}")
    tree = restore(path, {"params": params_like, "opt": opt_like})
    return tree["params"], tree["opt"], step
