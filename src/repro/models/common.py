"""Shared model building blocks.

Parameters are declared as ``ParamSpec`` trees (single source of truth for
shape, logical sharding axes and initializer).  From one spec tree we derive:

* ``materialize(spec, rng)``  -> real arrays (smoke tests, examples)
* ``abstract(spec)``          -> ShapeDtypeStructs (dry-run, no allocation)
* ``axes(spec)``              -> logical-axis tuples (sharding rules)

Logical axis names used across the repo::

    layers   stacking axis of a layer group        (never sharded)
    d_model  embedding dim                          (usually replicated)
    heads    query heads          -> "model"
    kv       kv heads             -> "model" when divisible
    head_dim per-head dim
    ffn      mlp intermediate     -> "model"
    experts  routed experts       -> "model" when divisible
    vocab    vocabulary           -> "model"
    state    ssm/rwkv state dims
    lora     mla/rwkv low-rank dims
    conv     conv kernel taps
"""
from __future__ import annotations

import math
import os
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed | scaled
    scale: float = 1.0


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(spec: ParamSpec, rng, dtype):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[0], 1)
    if spec.init == "embed":
        std = 0.02
    elif spec.init == "scaled":
        std = spec.scale / math.sqrt(fan_in)
    else:
        std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(rng, spec.shape) * std).astype(dtype)


def materialize(spec_tree, rng, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    rngs = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(l, r, dtype) for l, r in zip(leaves, rngs)]
    return jax.tree.unflatten(treedef, vals)


def abstract(spec_tree, dtype=jnp.float32):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), spec_tree,
        is_leaf=is_spec)


def axes(spec_tree):
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=is_spec)


def stack_specs(spec_tree, n: int, axis_name: str = "layers"):
    """Prepend a stacking dim of size ``n`` to every spec in the tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init,
                            s.scale),
        spec_tree, is_leaf=is_spec)


def param_bytes(spec_tree, bytes_per_el: int = 4) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) * bytes_per_el for s in leaves)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_spec(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("d_model",), "ones")}


def layernorm_spec(d: int) -> dict:
    return {"scale": ParamSpec((d,), ("d_model",), "ones"),
            "bias": ParamSpec((d,), ("d_model",), "zeros")}


def norm_spec(cfg) -> dict:
    return (layernorm_spec(cfg.d_model) if cfg.norm_type == "layernorm"
            else rmsnorm_spec(cfg.d_model))


# Flag gate for the fused Pallas RMSNorm (kernels/rmsnorm_2d through
# ops.rmsnorm_diff: Pallas forward in interpret mode on CPU, reference-
# recompute backward).  Off by default — the jnp path below stays the
# numerics baseline; enable via env REPRO_PALLAS_RMSNORM=1 or
# use_pallas_rmsnorm(True).  Parity vs the jnp reference is asserted by
# tests/test_kernels.py::test_apply_norm_pallas_gate_parity.
_PALLAS_RMSNORM = os.environ.get("REPRO_PALLAS_RMSNORM", "0") == "1"


def use_pallas_rmsnorm(enabled: bool) -> bool:
    """Toggle the fused RMSNorm path; returns the previous setting."""
    global _PALLAS_RMSNORM
    prev = _PALLAS_RMSNORM
    _PALLAS_RMSNORM = bool(enabled)
    return prev


def apply_norm(w, x, eps: float = 1e-6):
    if "bias" not in w and _PALLAS_RMSNORM:   # fused rmsnorm
        from repro.kernels import ops as kops
        return kops.rmsnorm_diff(x, w["scale"], eps=eps)
    xf = x.astype(jnp.float32)
    if "bias" in w:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * w["scale"].astype(jnp.float32) + w["bias"].astype(jnp.float32)
    else:            # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * w["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------
def act_fn(name: str) -> Callable:
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               fraction: float = 1.0) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    rd = int(d * fraction)
    rd -= rd % 2
    if rd == 0:
        return x
    xr, xp = x[..., :rd], x[..., rd:]
    freqs = rope_freqs(rd, theta)                     # (rd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, rd/2)
    ang = ang[..., None, :]                           # broadcast over heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., 0::2].astype(jnp.float32), xr[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rd < d else out


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def embed_spec(cfg) -> dict:
    spec = {"tok": ParamSpec((cfg.vocab_size, cfg.d_model),
                             ("vocab", "d_model"), "embed")}
    return spec


def head_spec(cfg) -> dict:
    if cfg.tie_embeddings:
        return {}
    return {"out": ParamSpec((cfg.d_model, cfg.vocab_size),
                             ("d_model", "vocab"))}


def embed_tokens(w, tokens, cfg, dtype):
    """Plain embedding row lookup — NO scaling for either norm type.

    Pinned behavior (tests/test_packing.py::test_embed_tokens_unscaled):
    a historical dead expression multiplied by 1.0 on both norm branches;
    the lookup is intentionally unscaled so this helper, ``model.prepare``
    (train/prefill) and ``model.decode_embed`` (decode) all agree on the
    same embedding values."""
    return jnp.take(w["tok"], tokens, axis=0).astype(dtype)


def logits_fn(head_w, embed_w, x, cfg):
    if cfg.tie_embeddings:
        w = embed_w["tok"].astype(x.dtype).T
    else:
        w = head_w["out"].astype(x.dtype)
    logits = x @ w
    if cfg.logit_soft_cap > 0:
        c = cfg.logit_soft_cap
        logits = c * jnp.tanh(logits / c)
    return logits


def softmax_xent(logits, targets, mask):
    """Cross-entropy, fp32 reduction.  mask: (B,S) weights."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return nll.sum(), mask.sum()


def compute_dtype(cfg):
    return jnp.dtype(cfg.dtype)
