"""State-space sequence mixers.

* ``mamba_*`` — selective SSM branch (Hymba's parallel attn+SSM heads).
  Training uses an associative scan (parallel prefix) over the sequence;
  decode is a single recurrent update, O(1) in context length.
* ``rwkv6_*`` — RWKV-6 "Finch" time-mix with data-dependent decay (DDLerp
  low-rank modulation) + channel-mix.  Attention-free; the decode state is
  a constant-size (H, hd, hd) matrix per layer.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec


# ===========================================================================
# Mamba-style selective SSM (Hymba branch)
# ===========================================================================
def mamba_spec(cfg) -> dict:
    d = cfg.d_model
    dI = cfg.d_model            # Hymba: SSM head width matches model dim
    N = cfg.ssm_state
    K = cfg.ssm_conv
    dt_rank = max(1, d // 16)
    return {
        "w_in": ParamSpec((d, 2 * dI), ("d_model", "ffn")),
        "conv": ParamSpec((K, dI), ("conv", "ffn"), "scaled", 1.0),
        "w_bcdt": ParamSpec((dI, 2 * N + dt_rank), ("ffn", "state")),
        "w_dt": ParamSpec((dt_rank, dI), ("state", "ffn")),
        "dt_bias": ParamSpec((dI,), ("ffn",), "zeros"),
        "a_log": ParamSpec((dI, N), ("ffn", "state"), "ones"),
        "d_skip": ParamSpec((dI,), ("ffn",), "ones"),
        "w_out": ParamSpec((dI, d), ("ffn", "d_model")),
    }


def _mamba_inner(w, xz, cfg, conv_state=None):
    """Shared projection part.  xz: (B,S,2*dI) -> (x_conv, z, dt, Bm, Cm)."""
    dI = cfg.d_model
    N = cfg.ssm_state
    x, z = xz[..., :dI], xz[..., dI:]
    # depthwise causal conv over seq
    K = w["conv"].shape[0]
    if conv_state is None:
        pads = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        pads = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    xc = sum(pads[:, i:i + x.shape[1], :] * w["conv"][i].astype(x.dtype)
             for i in range(K))
    xc = jax.nn.silu(xc)
    bcdt = xc @ w["w_bcdt"].astype(x.dtype)
    Bm, Cm, dt_low = bcdt[..., :N], bcdt[..., N:2 * N], bcdt[..., 2 * N:]
    dt = jax.nn.softplus(dt_low @ w["w_dt"].astype(x.dtype)
                         + w["dt_bias"].astype(x.dtype))     # (B,S,dI)
    new_conv_state = pads[:, -(K - 1):, :] if K > 1 else None
    return xc, z, dt, Bm, Cm, new_conv_state


def mamba_apply(w, x, cfg):
    """Full-sequence selective scan.  x: (B,S,d) -> (B,S,d)."""
    dt_ = x.dtype
    xz = x @ w["w_in"].astype(dt_)
    xc, z, dt, Bm, Cm, _ = _mamba_inner(w, xz, cfg)
    A = -jnp.exp(w["a_log"].astype(jnp.float32))             # (dI,N)
    # discretize: a = exp(dt*A), b = dt * B_t * x_t
    dtf = dt.astype(jnp.float32)
    a = jnp.exp(dtf[..., None] * A)                          # (B,S,dI,N)
    b = (dtf * xc.astype(jnp.float32))[..., None] * \
        Bm.astype(jnp.float32)[..., None, :]                 # (B,S,dI,N)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h * Cm.astype(jnp.float32)[..., None, :]).sum(-1)   # (B,S,dI)
    y = y + w["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y.astype(dt_)) * jax.nn.silu(z)
    return y @ w["w_out"].astype(dt_)


def mamba_state_spec(cfg, batch: int) -> dict:
    dI, N, K = cfg.d_model, cfg.ssm_state, cfg.ssm_conv
    return {
        "h": ParamSpec((batch, dI, N), ("batch", "ffn", "state"), "zeros"),
        "conv": ParamSpec((batch, K - 1, dI), ("batch", "conv", "ffn"),
                          "zeros"),
    }


def mamba_decode(w, x, state, cfg):
    """One step.  x: (B,1,d); state: {"h": (B,dI,N), "conv": (B,K-1,dI)}."""
    dt_ = x.dtype
    xz = x @ w["w_in"].astype(dt_)
    xc, z, dt, Bm, Cm, new_conv = _mamba_inner(w, xz, cfg,
                                               conv_state=state["conv"])
    A = -jnp.exp(w["a_log"].astype(jnp.float32))
    dtf = dt[:, 0].astype(jnp.float32)                       # (B,dI)
    a = jnp.exp(dtf[..., None] * A)                          # (B,dI,N)
    b = (dtf * xc[:, 0].astype(jnp.float32))[..., None] * \
        Bm[:, 0].astype(jnp.float32)[:, None, :]
    h = a * state["h"].astype(jnp.float32) + b
    y = (h * Cm[:, 0].astype(jnp.float32)[:, None, :]).sum(-1)
    y = y + w["d_skip"].astype(jnp.float32) * xc[:, 0].astype(jnp.float32)
    y = (y.astype(dt_) * jax.nn.silu(z[:, 0]))[:, None, :]
    out = y @ w["w_out"].astype(dt_)
    new_state = {"h": h.astype(state["h"].dtype),
                 "conv": new_conv.astype(state["conv"].dtype)}
    return out, new_state


# ===========================================================================
# RWKV-6 "Finch"
# ===========================================================================
def rwkv6_spec(cfg) -> dict:
    d = cfg.d_model
    H = cfg.rwkv_heads
    hd = cfg.rwkv_head_dim
    L = cfg.rwkv_lora
    ff = cfg.d_ff
    return {
        "tm": {  # time mix
            "mu_x": ParamSpec((d,), ("d_model",), "zeros"),
            "mu": ParamSpec((5, d), (None, "d_model"), "zeros"),  # r,k,v,g,w
            "lora_a": ParamSpec((d, 5 * 32), ("d_model", "lora")),
            "lora_b": ParamSpec((5, 32, d), (None, "lora", "d_model"),
                                "scaled", 0.1),
            "w_r": ParamSpec((d, d), ("d_model", "heads_x_dim")),
            "w_k": ParamSpec((d, d), ("d_model", "heads_x_dim")),
            "w_v": ParamSpec((d, d), ("d_model", "heads_x_dim")),
            "w_g": ParamSpec((d, d), ("d_model", "heads_x_dim")),
            "w0": ParamSpec((d,), ("d_model",), "zeros"),
            "decay_a": ParamSpec((d, L), ("d_model", "lora")),
            "decay_b": ParamSpec((L, d), ("lora", "d_model"), "scaled", 0.1),
            "u": ParamSpec((H, hd), ("heads", "head_dim"), "zeros"),
            "ln_scale": ParamSpec((d,), ("d_model",), "ones"),
            "w_o": ParamSpec((d, d), ("heads_x_dim", "d_model")),
        },
        "cm": {  # channel mix
            "mu_k": ParamSpec((d,), ("d_model",), "zeros"),
            "mu_r": ParamSpec((d,), ("d_model",), "zeros"),
            "w_k": ParamSpec((d, ff), ("d_model", "ffn")),
            "w_v": ParamSpec((ff, d), ("ffn", "d_model")),
            "w_r": ParamSpec((d, d), ("d_model", "d_model")),
        },
    }


def _shift(x, prev=None):
    """Token shift: x_{t-1} (zeros / carried state at t=0)."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev[:, None, :], x[:, :-1]], axis=1) \
        if x.shape[1] > 1 else prev[:, None, :]


def _ddlerp(w, x, xx):
    """Data-dependent lerp -> the 5 mixed inputs (r,k,v,g,w)."""
    dt_ = x.dtype
    base = x + (xx - x) * w["mu_x"].astype(dt_)
    dd = jnp.tanh(base @ w["lora_a"].astype(dt_))            # (B,S,5*32)
    B_, S_, _ = dd.shape
    dd = dd.reshape(B_, S_, 5, 32)
    mod = jnp.einsum("bsfl,fld->bsfd", dd, w["lora_b"].astype(dt_))
    mix = w["mu"].astype(dt_)[None, None] + mod              # (B,S,5,d)
    return x[:, :, None, :] + (xx - x)[:, :, None, :] * mix


def _rwkv_rkvgw(tm, x, xx, cfg):
    dt_ = x.dtype
    mixed = _ddlerp(tm, x, xx)
    xr, xk, xv, xg, xw = [mixed[:, :, i] for i in range(5)]
    r = xr @ tm["w_r"].astype(dt_)
    k = xk @ tm["w_k"].astype(dt_)
    v = xv @ tm["w_v"].astype(dt_)
    g = jax.nn.silu(xg @ tm["w_g"].astype(dt_))
    dec = tm["w0"].astype(jnp.float32) + jnp.tanh(
        xw.astype(jnp.float32) @ tm["decay_a"].astype(jnp.float32)
    ) @ tm["decay_b"].astype(jnp.float32)
    wdecay = jnp.exp(-jnp.exp(dec))                           # (B,S,d) in (0,1)
    return r, k, v, g, wdecay


def _heads(x, H, hd):
    B, S, _ = x.shape
    return x.reshape(B, S, H, hd)


def _wkv_step_scan(rh, kh, vh, wh, u, s0):
    """Reference step-by-step recurrence.  (B,H,S,hd) heads-major inputs."""
    def step(s, t):
        rt, kt, vt, wt = t                                  # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,hd,hd)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[..., :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, out

    xs = tuple(t.transpose(2, 0, 1, 3) for t in (rh, kh, vh, wh))
    s_fin, outs = jax.lax.scan(step, s0, xs)                # (S,B,H,hd)
    return outs.transpose(1, 2, 0, 3), s_fin                # (B,H,S,hd)


def _wkv_chunked(rh, kh, vh, wh, u, s0, chunk: int):
    """Chunked-parallel WKV6 (beyond-paper prefill optimization).

    Within a chunk of length L the recurrence unrolls into two matmuls
    via cumulative log-decays::

        out_t = â_t @ S_0 + [strict_tril(â k̃ᵀ) + diag(r·u·k)] @ V
        â_t = r_t ∘ exp(cum_{t-1}),  k̃_j = k_j ∘ exp(-cum_j)
        S_L  = exp(cum_L) ∘ S_0 + (k ∘ exp(cum_L - cum_j))ᵀ V

    which turns S sequential steps into S/L scan iterations of MXU-sized
    matmuls.  exp(-cum_j) grows with the in-chunk decay sum, so L is kept
    small (16 default: |cum| <= L·e keeps fp32 comfortably finite; the
    identity is asserted against the step scan in tests).
    inputs: (B,H,S,hd) heads-major.  Returns ((B,H,S,hd), S_end)."""
    B, H, S, hd = rh.shape
    L = chunk
    assert S % L == 0
    n = S // L

    def resh(t):
        return t.reshape(B, H, n, L, hd).transpose(2, 0, 1, 3, 4)

    rc, kc, vc = resh(rh), resh(kh), resh(vh)
    logw = jnp.log(jnp.maximum(resh(wh.astype(jnp.float32)), 1e-38))
    tri = jnp.tril(jnp.ones((L, L), jnp.float32), k=-1)

    def chunk_body(s, t):
        r, k, v, lw = t                       # (B,H,L,hd)
        cum = jnp.cumsum(lw, axis=2)          # cum_j, j=1..L
        cum_prev = cum - lw                   # cum_{t-1}
        a_hat = r * jnp.exp(cum_prev)
        k_tilde = k * jnp.exp(-cum)
        scores = jnp.einsum("bhtk,bhjk->bhtj", a_hat, k_tilde) * tri
        # u is (H, hd): the in-place bonus term, diagonal of the scores
        d_t = jnp.einsum("bhtk,hk,bhtk->bht", r, u, k)
        out = jnp.einsum("bhtj,bhjv->bhtv", scores, v) \
            + jnp.einsum("bhtk,bhkv->bhtv", a_hat, s) \
            + d_t[..., None] * v
        k_hat = k * jnp.exp(cum[:, :, -1:, :] - cum)
        s_new = jnp.exp(cum[:, :, -1, :])[..., None] * s + \
            jnp.einsum("bhjk,bhjv->bhkv", k_hat, v)
        return s_new, out

    s_fin, outs = jax.lax.scan(chunk_body, s0, (rc, kc, vc, logw))
    y = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, hd)
    return y, s_fin


def rwkv6_time_mix(tm, x, cfg, state=None):
    """Full-sequence WKV6.  x: (B,S,d).  Returns (y, new_wkv_state)."""
    B, S, d = x.shape
    H, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    prev = None if state is None else state.get("shift")
    xx = _shift(x, prev)
    r, k, v, g, wdecay = _rwkv_rkvgw(tm, x, xx, cfg)
    to_heads = lambda t: _heads(t, H, hd).transpose(0, 2, 1, 3)  # (B,H,S,hd)
    rh = to_heads(r).astype(jnp.float32)
    kh = to_heads(k).astype(jnp.float32)
    vh = to_heads(v).astype(jnp.float32)
    wh = to_heads(wdecay)
    u = tm["u"].astype(jnp.float32)

    s0 = (jnp.zeros((B, H, hd, hd), jnp.float32) if state is None
          else state["wkv"].astype(jnp.float32))

    chunk = getattr(cfg, "rwkv_chunk", 0)
    if chunk and S % chunk == 0 and S > chunk:
        outs, s_fin = _wkv_chunked(rh, kh, vh, wh, u, s0, chunk)
    else:
        outs, s_fin = _wkv_step_scan(rh, kh, vh, wh, u, s0)
    y = outs.transpose(0, 2, 1, 3).reshape(B, S, d)
    # per-head groupnorm
    yh = y.reshape(B, S, H, hd)
    mu = yh.mean(-1, keepdims=True)
    var = ((yh - mu) ** 2).mean(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(B, S, d) * tm["ln_scale"].astype(jnp.float32)
    y = (y.astype(x.dtype) * g) @ tm["w_o"].astype(x.dtype)
    new_state = {"wkv": s_fin, "shift": x[:, -1, :]}
    return y, new_state


def rwkv6_channel_mix(cm, x, state=None):
    dt_ = x.dtype
    prev = None if state is None else state.get("shift")
    xx = _shift(x, prev)
    xk = x + (xx - x) * cm["mu_k"].astype(dt_)
    xr = x + (xx - x) * cm["mu_r"].astype(dt_)
    kk = jnp.square(jax.nn.relu(xk @ cm["w_k"].astype(dt_)))
    out = jax.nn.sigmoid(xr @ cm["w_r"].astype(dt_)) * (kk @ cm["w_v"].astype(dt_))
    return out, {"shift": x[:, -1, :]}


def rwkv6_state_spec(cfg, batch: int) -> dict:
    H, hd, d = cfg.rwkv_heads, cfg.rwkv_head_dim, cfg.d_model
    return {
        "wkv": ParamSpec((batch, H, hd, hd), ("batch", "heads", "state",
                                              "state"), "zeros"),
        "tm_shift": ParamSpec((batch, d), ("batch", "d_model"), "zeros"),
        "cm_shift": ParamSpec((batch, d), ("batch", "d_model"), "zeros"),
    }
