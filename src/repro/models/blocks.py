"""Per-family transformer/SSM layer blocks with a uniform interface.

Every block family provides:

* ``spec(cfg)``                      — ParamSpec tree for ONE layer
* ``apply(w, x, mem, ctx, cfg)``     — full-seq forward -> (x', aux_scalar)
* ``decode(w, x, cache, mem, ctx, cfg)`` — one-token step -> (x', cache')
* ``cache_spec(cfg, batch, live)``   — per-layer decode cache ParamSpecs

``mem`` is the (differentiable) cross-attention memory (None except for
encoder-decoder stacks).  ``ctx`` carries non-differentiable context:
``positions`` (B,S) int32, ``mem_positions``, ``cur_pos`` (decode), and
``window`` (already baked as int).  The L2L engine computes per-layer VJPs
of ``apply`` w.r.t. (w, x, mem).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.common import ParamSpec, apply_norm, norm_spec
from repro.models.mlp import mlp_spec, mlp_apply
from repro.models.moe import moe_spec, moe_apply


class Ctx(NamedTuple):
    positions: Optional[jnp.ndarray] = None       # (B,S) int32
    mem_positions: Optional[jnp.ndarray] = None   # (B,Sm) int32
    cur_pos: Optional[jnp.ndarray] = None         # scalar int32 (decode)
    window: int = 0                               # sliding window (0 = full)
    causal: bool = True


def _norm(w, x, cfg):
    return apply_norm(w, x, cfg.norm_eps)


# ===========================================================================
# Dense decoder block (command-r / qwen / chatglm / granite / internvl-LM)
# ===========================================================================
def dense_spec(cfg) -> dict:
    spec = {"ln1": norm_spec(cfg), "attn": attn.gqa_spec(cfg),
            "mlp": mlp_spec(cfg)}
    if not cfg.parallel_block:
        spec["ln2"] = norm_spec(cfg)
    return spec


def dense_apply(w, x, mem, ctx: Ctx, cfg):
    if cfg.parallel_block:      # command-r: attn ∥ mlp off one norm
        h = _norm(w["ln1"], x, cfg)
        a = attn.self_attention(w["attn"], h, cfg, ctx.positions,
                                causal=ctx.causal, window=ctx.window)
        m = mlp_apply(w["mlp"], h, cfg)
        return x + a + m, jnp.float32(0.0)
    h = _norm(w["ln1"], x, cfg)
    x = x + attn.self_attention(w["attn"], h, cfg, ctx.positions,
                                causal=ctx.causal, window=ctx.window)
    x = x + mlp_apply(w["mlp"], _norm(w["ln2"], x, cfg), cfg)
    return x, jnp.float32(0.0)


def dense_decode(w, x, cache, mem, ctx: Ctx, cfg):
    if cfg.parallel_block:
        h = _norm(w["ln1"], x, cfg)
        a, cache = attn.decode_self_attention(w["attn"], h, cache, cfg,
                                              ctx.cur_pos, window=ctx.window)
        m = mlp_apply(w["mlp"], h, cfg)
        return x + a + m, cache
    h = _norm(w["ln1"], x, cfg)
    a, cache = attn.decode_self_attention(w["attn"], h, cache, cfg,
                                          ctx.cur_pos, window=ctx.window)
    x = x + a
    x = x + mlp_apply(w["mlp"], _norm(w["ln2"], x, cfg), cfg)
    return x, cache


def dense_cache_spec(cfg, batch, live):
    return attn.kv_cache_spec(cfg, batch, live)


# ===========================================================================
# MoE block (grok) and MLA+MoE block (deepseek-v2)
# ===========================================================================
def moe_block_spec(cfg, dense_ffn: bool = False) -> dict:
    a_spec = attn.mla_spec(cfg) if cfg.use_mla else attn.gqa_spec(cfg)
    ffn = (mlp_spec(cfg, cfg.d_ff_dense or cfg.d_ff) if dense_ffn
           else moe_spec(cfg))
    return {"ln1": norm_spec(cfg), "attn": a_spec, "ln2": norm_spec(cfg),
            "ffn": ffn}


def moe_block_apply(w, x, mem, ctx: Ctx, cfg):
    h = _norm(w["ln1"], x, cfg)
    if cfg.use_mla:
        a = attn.mla_attention(w["attn"], h, cfg, ctx.positions,
                               causal=ctx.causal, window=ctx.window)
    else:
        a = attn.self_attention(w["attn"], h, cfg, ctx.positions,
                                causal=ctx.causal, window=ctx.window)
    x = x + a
    h2 = _norm(w["ln2"], x, cfg)
    if "router" in w["ffn"]:
        y, aux = moe_apply(w["ffn"], h2, cfg)
    else:
        y, aux = mlp_apply(w["ffn"], h2, cfg), jnp.float32(0.0)
    return x + y, aux


def moe_block_decode(w, x, cache, mem, ctx: Ctx, cfg):
    h = _norm(w["ln1"], x, cfg)
    if cfg.use_mla:
        a, cache = attn.decode_mla_attention(w["attn"], h, cache, cfg,
                                             ctx.cur_pos, window=ctx.window)
    else:
        a, cache = attn.decode_self_attention(w["attn"], h, cache, cfg,
                                              ctx.cur_pos, window=ctx.window)
    x = x + a
    h2 = _norm(w["ln2"], x, cfg)
    if "router" in w["ffn"]:
        y, _ = moe_apply(w["ffn"], h2, cfg)
    else:
        y = mlp_apply(w["ffn"], h2, cfg)
    return x + y, cache


# ===========================================================================
# Hybrid block (hymba: parallel attention + mamba heads)
# ===========================================================================
def hybrid_spec(cfg) -> dict:
    return {"ln1": norm_spec(cfg), "attn": attn.gqa_spec(cfg),
            "mamba": ssm_mod.mamba_spec(cfg),
            "beta_a": ParamSpec((cfg.d_model,), ("d_model",), "ones"),
            "beta_s": ParamSpec((cfg.d_model,), ("d_model",), "ones"),
            "ln2": norm_spec(cfg), "mlp": mlp_spec(cfg)}


def hybrid_apply(w, x, mem, ctx: Ctx, cfg):
    h = _norm(w["ln1"], x, cfg)
    a = attn.self_attention(w["attn"], h, cfg, ctx.positions,
                            causal=ctx.causal, window=ctx.window)
    s = ssm_mod.mamba_apply(w["mamba"], h, cfg)
    fused = 0.5 * (a * w["beta_a"].astype(x.dtype)
                   + s * w["beta_s"].astype(x.dtype))
    x = x + fused
    x = x + mlp_apply(w["mlp"], _norm(w["ln2"], x, cfg), cfg)
    return x, jnp.float32(0.0)


def hybrid_decode(w, x, cache, mem, ctx: Ctx, cfg):
    h = _norm(w["ln1"], x, cfg)
    a, kv = attn.decode_self_attention(w["attn"], h, cache["kv"], cfg,
                                       ctx.cur_pos, window=ctx.window)
    s, st = ssm_mod.mamba_decode(w["mamba"], h, cache["ssm"], cfg)
    fused = 0.5 * (a * w["beta_a"].astype(x.dtype)
                   + s * w["beta_s"].astype(x.dtype))
    x = x + fused
    x = x + mlp_apply(w["mlp"], _norm(w["ln2"], x, cfg), cfg)
    return x, {"kv": kv, "ssm": st}


def hybrid_cache_spec(cfg, batch, live):
    return {"kv": attn.kv_cache_spec(cfg, batch, live),
            "ssm": ssm_mod.mamba_state_spec(cfg, batch)}


# ===========================================================================
# RWKV6 block (attention-free)
# ===========================================================================
def rwkv_spec(cfg) -> dict:
    return {"ln1": norm_spec(cfg), **ssm_mod.rwkv6_spec(cfg),
            "ln2": norm_spec(cfg)}


def rwkv_apply(w, x, mem, ctx: Ctx, cfg):
    y, _ = ssm_mod.rwkv6_time_mix(w["tm"], _norm(w["ln1"], x, cfg), cfg)
    x = x + y
    y, _ = ssm_mod.rwkv6_channel_mix(w["cm"], _norm(w["ln2"], x, cfg))
    return x + y, jnp.float32(0.0)


def rwkv_decode(w, x, cache, mem, ctx: Ctx, cfg):
    tm_state = {"wkv": cache["wkv"], "shift": cache["tm_shift"]}
    y, tm_new = ssm_mod.rwkv6_time_mix(w["tm"], _norm(w["ln1"], x, cfg),
                                       cfg, state=tm_state)
    x = x + y
    y, cm_new = ssm_mod.rwkv6_channel_mix(
        w["cm"], _norm(w["ln2"], x, cfg), state={"shift": cache["cm_shift"]})
    x = x + y
    new_cache = {"wkv": tm_new["wkv"].astype(cache["wkv"].dtype),
                 "tm_shift": tm_new["shift"].astype(cache["tm_shift"].dtype),
                 "cm_shift": cm_new["shift"].astype(cache["cm_shift"].dtype)}
    return x, new_cache


def rwkv_cache_spec(cfg, batch, live):
    return ssm_mod.rwkv6_state_spec(cfg, batch)


# ===========================================================================
# Whisper encoder / decoder blocks (layernorm + biased projections + gelu)
# ===========================================================================
def whisper_enc_spec(cfg) -> dict:
    return {"ln1": norm_spec(cfg), "attn": attn.gqa_spec(cfg),
            "ln2": norm_spec(cfg), "mlp": mlp_spec(cfg)}


def whisper_enc_apply(w, x, mem, ctx: Ctx, cfg):
    h = _norm(w["ln1"], x, cfg)
    x = x + attn.self_attention(w["attn"], h, cfg, ctx.positions,
                                causal=False, rope=False)
    x = x + mlp_apply(w["mlp"], _norm(w["ln2"], x, cfg), cfg)
    return x, jnp.float32(0.0)


def whisper_dec_spec(cfg) -> dict:
    return {"ln1": norm_spec(cfg), "attn": attn.gqa_spec(cfg),
            "ln_x": norm_spec(cfg), "xattn": attn.gqa_spec(cfg, cross=True),
            "ln2": norm_spec(cfg), "mlp": mlp_spec(cfg)}


def whisper_dec_apply(w, x, mem, ctx: Ctx, cfg):
    h = _norm(w["ln1"], x, cfg)
    x = x + attn.self_attention(w["attn"], h, cfg, ctx.positions,
                                causal=True, rope=False)
    h = _norm(w["ln_x"], x, cfg)
    x = x + attn.cross_attention(w["xattn"], h, mem, cfg, ctx.positions,
                                 ctx.mem_positions)
    x = x + mlp_apply(w["mlp"], _norm(w["ln2"], x, cfg), cfg)
    return x, jnp.float32(0.0)


def whisper_dec_decode(w, x, cache, mem, ctx: Ctx, cfg):
    """Self-attn against the ring cache; cross-attn against precomputed
    encoder K/V stored in the cache (computed once at prefill)."""
    dt = x.dtype
    h = _norm(w["ln1"], x, cfg)
    a, kv = attn.decode_self_attention(w["attn"], h, cache["kv"], cfg,
                                       ctx.cur_pos, window=ctx.window,
                                       rope=False)
    x = x + a
    h = _norm(w["ln_x"], x, cfg)
    q = jnp.einsum("bsd,dhe->bshe", h, w["xattn"]["wq"].astype(dt))
    if "bq" in w["xattn"]:
        q = q + w["xattn"]["bq"].astype(dt)
    B = x.shape[0]
    pos = jnp.full((B, 1), ctx.cur_pos, jnp.int32)
    mpos = jnp.broadcast_to(jnp.arange(cache["xk"].shape[1], dtype=jnp.int32),
                            (B, cache["xk"].shape[1]))
    o = attn.attend(q, attn.expand_kv(cache["xk"].astype(dt), cfg.n_q_per_kv),
                    attn.expand_kv(cache["xv"].astype(dt), cfg.n_q_per_kv),
                    pos, mpos, causal=False, chunk=0)
    x = x + attn.out_project(w["xattn"], o)
    x = x + mlp_apply(w["mlp"], _norm(w["ln2"], x, cfg), cfg)
    return x, {**cache, "kv": kv}


def whisper_dec_cache_spec(cfg, batch, live):
    KV, Dh = cfg.n_kv_heads, cfg.d_head
    return {
        "kv": attn.kv_cache_spec(cfg, batch, live),
        "xk": ParamSpec((batch, cfg.n_frames, KV, Dh),
                        ("batch", "seq", "kv", "head_dim"), "zeros"),
        "xv": ParamSpec((batch, cfg.n_frames, KV, Dh),
                        ("batch", "seq", "kv", "head_dim"), "zeros"),
    }
