"""Mixture-of-Experts: token-choice top-k routing.

Two dispatch paths:

* **capacity path** (training / prefill, T large): Switch-style cumsum slot
  assignment, scatter into per-expert buffers ``(E, C, d)``, batched expert
  matmuls, weighted scatter-add combine.  FLOPs scale with ``top_k`` (times
  the capacity factor), *not* with E — this keeps the roofline's
  MODEL_FLOPS/HLO_FLOPs ratio honest.
* **dense path** (decode, T <= 2E): compute every expert for every token and
  combine with the top-k weights.  Exact (no capacity drops) and cheap when
  only a handful of tokens are live.

Experts are sharded over the ``model`` mesh axis when E divides it
(expert parallelism — deepseek), otherwise the expert FFN dim is sharded
(tensor parallelism within experts — grok).  See distributed/sharding.py.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, act_fn
from repro.models.mlp import mlp_spec, mlp_apply


def moe_spec(cfg) -> dict:
    E, d, fe = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    experts = {
        "w_gate": ParamSpec((E, d, fe), ("experts", "d_model", "expert_ffn")),
        "w_in": ParamSpec((E, d, fe), ("experts", "d_model", "expert_ffn")),
        "w_out": ParamSpec((E, fe, d), ("experts", "expert_ffn", "d_model")),
    }
    if not cfg.gated_mlp:
        experts = {
            "w_in": ParamSpec((E, d, fe), ("experts", "d_model", "expert_ffn")),
            "w_out": ParamSpec((E, fe, d), ("experts", "expert_ffn", "d_model")),
        }
    spec = {
        "router": ParamSpec((d, E), ("d_model", "experts"), "scaled", 0.1),
        "experts": experts,
    }
    if cfg.n_shared_experts:
        spec["shared"] = mlp_spec(cfg, cfg.n_shared_experts * fe)
    return spec


def _expert_ffn(w, x, cfg):
    """x: (E, C, d) -> (E, C, d), batched over experts."""
    dt = x.dtype
    act = act_fn(cfg.act)
    if "w_gate" in w:
        h = act(jnp.einsum("ecd,edf->ecf", x, w["w_gate"].astype(dt)))
        h = h * jnp.einsum("ecd,edf->ecf", x, w["w_in"].astype(dt))
    else:
        h = act(jnp.einsum("ecd,edf->ecf", x, w["w_in"].astype(dt)))
    return jnp.einsum("ecf,efd->ecd", h, w["w_out"].astype(dt))


def _route(w, xf, cfg):
    """xf: (T,d) -> top-k (weights (T,k) fp32, ids (T,k) int32, aux loss)."""
    logits = (xf.astype(jnp.float32) @ w["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # (T,E)
    top_w, top_i = jax.lax.top_k(probs, cfg.experts_per_token)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    # load-balance aux (Switch): E * sum_e f_e * p_e
    E = cfg.n_experts
    f = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    f = f / jnp.maximum(top_i.size, 1)
    p = probs.mean(0)
    aux = E * jnp.sum(f * p) * cfg.router_aux_coef
    return top_w, top_i, aux


def _moe_dense(w, xf, top_w, top_i, cfg):
    """All-experts path for tiny T (decode)."""
    E = cfg.n_experts
    y_all = _expert_ffn(w["experts"], jnp.broadcast_to(
        xf[None], (E,) + xf.shape), cfg)                      # (E,T,d)
    gate = jnp.zeros((xf.shape[0], E), jnp.float32)
    gate = jnp.take_along_axis(
        gate, top_i, axis=1)  # placeholder to keep shapes; replaced below
    onehot = jax.nn.one_hot(top_i, E, dtype=jnp.float32)      # (T,k,E)
    comb = (onehot * top_w[..., None]).sum(1)                 # (T,E)
    return jnp.einsum("te,etd->td", comb.astype(xf.dtype), y_all)


def _ep_constraint(x):
    """Beyond-paper (§Perf): pin the expert-dispatch buffers' sharding.

    x: (E, C, d).  Without this, the capacity dim C (sized from the GLOBAL
    token count under pjit semantics) stays unsharded, so every data
    replica computes the full global capacity — a dp-fold FLOPs inflation
    observed in the dry-run (16x on the single-pod mesh).  Sharding C over
    the data axes makes the scatter into the buffer the classic MoE
    all-to-all (tokens cross data shards to reach their expert slots) and
    right-sizes per-device expert compute; E additionally shards over
    "model" when divisible (expert parallel — deepseek), else d does
    (tensor parallel inside experts — grok).  No-op without a mesh.
    """
    from jax.sharding import PartitionSpec as P
    from jax._src.mesh import thread_resources
    import numpy as np
    mesh = thread_resources.env.physical_mesh
    if mesh is None or mesh.empty or "model" not in (mesh.axis_names or ()):
        return x
    m = mesh.shape["model"]
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1
    E, C, d = x.shape
    e_ax = "model" if (E % m == 0) else None
    c_ax = (data_axes if (data_axes and C % dp == 0) else None)
    d_ax = "model" if (e_ax is None and d % m == 0) else None
    if e_ax is None and c_ax is None and d_ax is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(e_ax, c_ax, d_ax))


def _dispatch(xf, top_i, C, E, k):
    """Token-choice slot assignment for one dispatch group.
    xf: (T,d) -> buf (E, C+1, d), slot_c (Tk,), keep (Tk,), flat_e, tok_idx."""
    T, d = xf.shape
    flat_e = top_i.reshape(T * k)                             # (Tk,)
    tok_idx = jnp.arange(T * k, dtype=jnp.int32) // k
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)       # (Tk,E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = slot < C
    slot_c = jnp.where(keep, slot, C)                         # overflow -> C
    buf = jnp.zeros((E, C + 1, d), xf.dtype)
    buf = buf.at[flat_e, slot_c].set(xf[tok_idx])
    return buf, slot_c, keep, flat_e, tok_idx


def _combine(y_pad, top_w, slot_c, keep, flat_e, tok_idx, T, d):
    """y_pad: (E, C+1, d) expert outputs -> (T, d)."""
    flat_w = top_w.reshape(-1)
    gathered = y_pad[flat_e, slot_c]                          # (Tk,d)
    gathered = gathered * (flat_w * keep).astype(y_pad.dtype)[:, None]
    return jnp.zeros((T, d), y_pad.dtype).at[tok_idx].add(gathered)


def _dispatch_groups(cfg, T):
    """Local-dispatch group count == data-parallel shard count.

    Beyond-paper (§Perf): a single GLOBAL dispatch sizes the capacity
    buffer from the global token count and its slot cumsum couples all
    data shards, so the partitioner replicates the (E, C_global, d)
    buffer on every data shard (dp-fold expert FLOPs) or falls back to
    full rematerialization.  Splitting tokens into per-data-shard groups
    makes the cumsum local, the buffer (E, G, C_local, d) fully sharded,
    and the scatter across shards the classic MoE all-to-all."""
    if not cfg.moe_ep_constraint:
        return 1
    from jax._src.mesh import thread_resources
    import numpy as np
    mesh = thread_resources.env.physical_mesh
    if mesh is None or mesh.empty:
        return 1
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    G = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    return G if (G > 1 and T % G == 0) else 1


def _moe_capacity(w, xf, top_w, top_i, cfg):
    T, d = xf.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    G = _dispatch_groups(cfg, T)
    Tl = T // G
    C = max(1, int(math.ceil(Tl * k / E * cfg.capacity_factor)))
    C = min(C, Tl)
    if G == 1:
        buf, slot_c, keep, flat_e, tok_idx = _dispatch(xf, top_i, C, E, k)
        if cfg.moe_ep_constraint:
            buf = _ep_constraint(buf)
        y = _expert_ffn(w["experts"], buf[:, :C], cfg)        # (E,C,d)
        if cfg.moe_ep_constraint:
            y = _ep_constraint(y)
        y = jnp.pad(y, ((0, 0), (0, 1), (0, 0)))              # slot C == 0
        return _combine(y, top_w, slot_c, keep, flat_e, tok_idx, T, d)

    # ---- grouped local dispatch (one group per data shard) -------------
    xg = xf.reshape(G, Tl, d)
    tig = top_i.reshape(G, Tl, k)
    twg = top_w.reshape(G, Tl, k)
    bufs, slot_c, keep, flat_e, tok_idx = jax.vmap(
        lambda x, ti: _dispatch(x, ti, C, E, k))(xg, tig)     # (G,E,C+1,d)
    buf = bufs.transpose(1, 0, 2, 3)                          # (E,G,C+1,d)
    buf = _ep_constraint_grouped(buf)
    dt = buf.dtype
    act = act_fn(cfg.act)
    xb = buf[:, :, :C]
    if "w_gate" in w["experts"]:
        h = act(jnp.einsum("egcd,edf->egcf", xb,
                           w["experts"]["w_gate"].astype(dt)))
        h = h * jnp.einsum("egcd,edf->egcf", xb,
                           w["experts"]["w_in"].astype(dt))
    else:
        h = act(jnp.einsum("egcd,edf->egcf", xb,
                           w["experts"]["w_in"].astype(dt)))
    y = jnp.einsum("egcf,efd->egcd", h, w["experts"]["w_out"].astype(dt))
    y = _ep_constraint_grouped(jnp.pad(y, ((0, 0), (0, 0), (0, 1), (0, 0))))
    yg = y.transpose(1, 0, 2, 3)                              # (G,E,C+1,d)
    out = jax.vmap(
        lambda yp, tw, sc, kp, fe, ti: _combine(yp, tw, sc, kp, fe, ti,
                                                Tl, d)
    )(yg, twg, slot_c, keep, flat_e, tok_idx)
    return out.reshape(T, d)


def _ep_constraint_grouped(x):
    """(E, G, C, d): E over 'model' when divisible, G over the data axes."""
    from jax.sharding import PartitionSpec as P
    from jax._src.mesh import thread_resources
    import numpy as np
    mesh = thread_resources.env.physical_mesh
    if mesh is None or mesh.empty or "model" not in (mesh.axis_names or ()):
        return x
    m = mesh.shape["model"]
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1
    E, G, _, d = x.shape
    e_ax = "model" if E % m == 0 else None
    g_ax = data_axes if (data_axes and G % dp == 0) else None
    d_ax = "model" if (e_ax is None and d % m == 0) else None
    return jax.lax.with_sharding_constraint(x, P(e_ax, g_ax, None, d_ax))


def moe_apply(w, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,d) -> (y, aux_loss)."""
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    top_w, top_i, aux = _route(w, xf, cfg)
    if B * S <= 2 * cfg.n_experts:
        y = _moe_dense(w, xf, top_w, top_i, cfg)
    else:
        y = _moe_capacity(w, xf, top_w, top_i, cfg)
    if "shared" in w:
        y = y + mlp_apply(w["shared"], xf, cfg)
    return y.reshape(B, S, d), aux
