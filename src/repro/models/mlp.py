"""Dense feed-forward blocks (gated SwiGLU-style and plain GELU MLP)."""
from __future__ import annotations


from repro.models.common import ParamSpec, act_fn


def mlp_spec(cfg, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    if cfg.gated_mlp:
        return {
            "w_gate": ParamSpec((d, ff), ("d_model", "ffn")),
            "w_in": ParamSpec((d, ff), ("d_model", "ffn")),
            "w_out": ParamSpec((ff, d), ("ffn", "d_model")),
        }
    return {
        "w_in": ParamSpec((d, ff), ("d_model", "ffn")),
        "b_in": ParamSpec((ff,), ("ffn",), "zeros"),
        "w_out": ParamSpec((ff, d), ("ffn", "d_model")),
        "b_out": ParamSpec((d,), ("d_model",), "zeros"),
    }


def mlp_apply(w, x, cfg):
    dt = x.dtype
    act = act_fn(cfg.act)
    if "w_gate" in w:
        h = act(x @ w["w_gate"].astype(dt)) * (x @ w["w_in"].astype(dt))
        return h @ w["w_out"].astype(dt)
    h = act(x @ w["w_in"].astype(dt) + w["b_in"].astype(dt))
    return h @ w["w_out"].astype(dt) + w["b_out"].astype(dt)
