"""Attention: GQA / MHA, MLA (DeepSeek-V2), sliding-window, cross-attention,
memory-efficient chunked softmax, and decode paths against KV caches
(full, ring-buffer windowed, and MLA-compressed with the absorbed-matmul
trick).

All functions are pure; parameters come in as pytrees built from the
``ParamSpec`` trees declared here.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, apply_rope, apply_norm, rmsnorm_spec

NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------
def gqa_spec(cfg, cross: bool = False) -> dict:
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    spec = {
        "wq": ParamSpec((d, H, Dh), ("d_model", "heads", "head_dim")),
        "wk": ParamSpec((d, KV, Dh), ("d_model", "kv", "head_dim")),
        "wv": ParamSpec((d, KV, Dh), ("d_model", "kv", "head_dim")),
        "wo": ParamSpec((H, Dh, d), ("heads", "head_dim", "d_model")),
    }
    if cfg.qkv_bias:
        spec["bq"] = ParamSpec((H, Dh), ("heads", "head_dim"), "zeros")
        spec["bk"] = ParamSpec((KV, Dh), ("kv", "head_dim"), "zeros")
        spec["bv"] = ParamSpec((KV, Dh), ("kv", "head_dim"), "zeros")
    if cfg.o_bias:
        spec["bo"] = ParamSpec((d,), ("d_model",), "zeros")
    return spec


def mla_spec(cfg) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    r, nd, rd, vd = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    return {
        "wq": ParamSpec((d, H, nd + rd), ("d_model", "heads", "head_dim")),
        "w_dkv": ParamSpec((d, r), ("d_model", "lora")),
        "w_kr": ParamSpec((d, rd), ("d_model", "head_dim")),
        "kv_norm": rmsnorm_spec(r)["scale"]._replace(axes=("lora",)),
        "w_uk": ParamSpec((r, H, nd), ("lora", "heads", "head_dim")),
        "w_uv": ParamSpec((r, H, vd), ("lora", "heads", "head_dim")),
        "wo": ParamSpec((H, vd, d), ("heads", "head_dim", "d_model")),
    }


# ---------------------------------------------------------------------------
# Core softmax attention (chunked, online-softmax — the jnp analogue of the
# Pallas flash kernel in repro/kernels/flash_attention.py)
# ---------------------------------------------------------------------------
def _mask(q_pos, k_pos, causal: bool, window: int):
    """q_pos: (B,Sq), k_pos: (B,Sk) -> allow (B,1,Sq,Sk).  Slots with
    k_pos < 0 are invalid (ring-buffer holes)."""
    qp = q_pos[:, None, :, None]
    kp = k_pos[:, None, None, :]
    allow = kp >= 0
    if causal:
        allow &= kp <= qp
    if window > 0:
        allow &= qp - kp < window
    return allow


def attend(q, k, v, q_pos, k_pos, *, causal: bool, window: int = 0,
           chunk: int = 0, soft_cap: float = 0.0):
    """q: (B,Sq,H,D); k,v: (B,Sk,H,D) (kv heads already expanded to H).

    Returns (B,Sq,H,D).  ``chunk``>0 streams over KV chunks with an online
    softmax so the (Sq,Sk) score matrix is never fully materialized.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    qf = (q * scale).astype(jnp.float32)

    def scores_of(k_c, kpos_c):
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, k_c.astype(jnp.float32))
        if soft_cap > 0:
            s = soft_cap * jnp.tanh(s / soft_cap)
        allow = _mask(q_pos, kpos_c, causal, window)
        return jnp.where(allow, s, NEG_INF)

    if chunk <= 0 or Sk <= chunk:
        s = scores_of(k, k_pos)
        m = s.max(-1, keepdims=True)
        p = jnp.exp(s - m)
        l = p.sum(-1, keepdims=True)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
        o = o / jnp.maximum(l, 1e-30).transpose(0, 2, 1, 3)
        return o.astype(q.dtype)

    if Sk % chunk:
        # pad KV to a chunk multiple; padded slots get k_pos = -1 (masked)
        pad = (-Sk) % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        Sk += pad
    n_chunks = Sk // chunk
    k_cs = k.reshape(B, n_chunks, chunk, H, D).transpose(1, 0, 2, 3, 4)
    v_cs = v.reshape(B, n_chunks, chunk, H, D).transpose(1, 0, 2, 3, 4)
    kp_cs = k_pos.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        m, l, acc = carry
        k_c, v_c, kp_c = xs
        s = scores_of(k_c, kp_c)                         # (B,H,Sq,C) fp32
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, v_c.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (k_cs, v_cs, kp_cs))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 2, 1, 3).astype(q.dtype)


def attend_grouped_decode(q, k, v, q_pos, k_pos, *, causal: bool,
                          window: int = 0, soft_cap: float = 0.0):
    """Decode attention WITHOUT materializing expanded KV heads.

    Beyond-paper optimization (§Perf): `expand_kv` under pjit broadcasts
    the (B,S,KV,D) cache into a head-sharded (B,S,H,D) layout — the SPMD
    partitioner can't reshard that efficiently and falls back to full
    rematerialization (~GBs of all-gather per layer per step).  Keeping the
    KV head dim grouped makes every einsum a plain batch contraction over
    the seq-sharded cache: softmax partials + one psum, no broadcast.

    q: (B,1,H,D); k,v: (B,S,KV,D) -> (B,1,H,D)
    """
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    q5 = (q * scale).reshape(B, Sq, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q5, k.astype(jnp.float32))
    if soft_cap > 0:
        s = soft_cap * jnp.tanh(s / soft_cap)
    allow = _mask(q_pos, k_pos, causal, window)          # (B,1,Sq,S)
    s = jnp.where(allow[:, :, None, :, :], s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgqs,bskd->bqkgd", (p / l), v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def expand_kv(k, n_q_per_kv):
    """(B,S,KV,D) -> (B,S,KV*n,D) by repeating each kv head."""
    if n_q_per_kv == 1:
        return k
    B, S, KV, D = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, n_q_per_kv, D))
    return k.reshape(B, S, KV * n_q_per_kv, D)


# ---------------------------------------------------------------------------
# GQA self / cross attention (training & prefill: full sequence)
# ---------------------------------------------------------------------------
def qkv_project(w, x, cfg, positions, *, rope: bool = True):
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, w["wq"].astype(dt))
    k = jnp.einsum("bsd,dke->bske", x, w["wk"].astype(dt))
    v = jnp.einsum("bsd,dke->bske", x, w["wv"].astype(dt))
    if "bq" in w:
        q = q + w["bq"].astype(dt)
        k = k + w["bk"].astype(dt)
        v = v + w["bv"].astype(dt)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    return q, k, v


def out_project(w, o):
    dt = o.dtype
    y = jnp.einsum("bshe,hed->bsd", o, w["wo"].astype(dt))
    if "bo" in w:
        y = y + w["bo"].astype(dt)
    return y


def self_attention(w, x, cfg, positions, *, causal: bool = True,
                   window: int = 0, rope: bool = True):
    """Full-sequence self attention (train / prefill)."""
    q, k, v = qkv_project(w, x, cfg, positions, rope=rope)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, expand_kv(k, cfg.n_q_per_kv),
                                 expand_kv(v, cfg.n_q_per_kv),
                                 causal=causal, window=window,
                                 soft_cap=0.0)
    else:
        o = attend(q, expand_kv(k, cfg.n_q_per_kv),
                   expand_kv(v, cfg.n_q_per_kv), positions, positions,
                   causal=causal, window=window, chunk=cfg.attn_chunk)
    return out_project(w, o)


def cross_attention(w, x, mem, cfg, positions, mem_positions):
    """x attends to mem (whisper decoder -> encoder)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, w["wq"].astype(dt))
    if "bq" in w:
        q = q + w["bq"].astype(dt)
    k = jnp.einsum("bsd,dke->bske", mem, w["wk"].astype(dt))
    v = jnp.einsum("bsd,dke->bske", mem, w["wv"].astype(dt))
    if "bk" in w:
        k = k + w["bk"].astype(dt)
        v = v + w["bv"].astype(dt)
    o = attend(q, expand_kv(k, cfg.n_q_per_kv),
               expand_kv(v, cfg.n_q_per_kv), positions, mem_positions,
               causal=False, chunk=0)
    return out_project(w, o)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) — train/prefill
# ---------------------------------------------------------------------------
def mla_attention(w, x, cfg, positions, *, causal: bool = True,
                  window: int = 0):
    dt = x.dtype
    B, S, _ = x.shape
    H = cfg.n_heads
    nd, rd = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,dhe->bshe", x, w["wq"].astype(dt))   # (B,S,H,nd+rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c = x @ w["w_dkv"].astype(dt)                            # (B,S,r)
    c = apply_norm({"scale": w["kv_norm"]}, c, cfg.norm_eps)
    k_rope = (x @ w["w_kr"].astype(dt))[:, :, None, :]       # (B,S,1,rd)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    k_nope = jnp.einsum("bsr,rhe->bshe", c, w["w_uk"].astype(dt))
    v = jnp.einsum("bsr,rhe->bshe", c, w["w_uv"].astype(dt))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, rd))], axis=-1)
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    # pad v up to qk dim for the shared attend() then slice back
    o = attend(qq, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                  (0, qq.shape[-1] - v.shape[-1]))),
               positions, positions, causal=causal, window=window,
               chunk=cfg.attn_chunk)[..., :cfg.v_head_dim]
    return jnp.einsum("bshe,hed->bsd", o, w["wo"].astype(dt))


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------
def kv_cache_spec(cfg, batch: int, seq: int) -> dict:
    """Per-layer cache spec (the layer stack dim is prepended by the model).

    ``seq`` here is the *live* cache length: the full context for ordinary
    decode, or the ring-buffer window for long-context decode."""
    if cfg.use_mla:
        r, rd = cfg.kv_lora_rank, cfg.qk_rope_dim
        return {
            "c": ParamSpec((batch, seq, r), ("batch", "seq", "lora"), "zeros"),
            "kr": ParamSpec((batch, seq, rd), ("batch", "seq", "head_dim"),
                            "zeros"),
            "pos": ParamSpec((batch, seq), ("batch", "seq"), "zeros"),
        }
    KV, Dh = cfg.n_kv_heads, cfg.d_head
    return {
        "k": ParamSpec((batch, seq, KV, Dh), ("batch", "seq", "kv", "head_dim"),
                       "zeros"),
        "v": ParamSpec((batch, seq, KV, Dh), ("batch", "seq", "kv", "head_dim"),
                       "zeros"),
        "pos": ParamSpec((batch, seq), ("batch", "seq"), "zeros"),
    }


def _ring_index(cur_pos, cache_len):
    return jnp.mod(cur_pos, cache_len)


def decode_positions(x, cur_pos):
    """Normalize a decode position argument to a (B, T) int32 array.

    ``cur_pos`` is either the historical scalar (one shared absolute
    position; T must be 1) or a (B,) / (B, T) per-row position array — the
    continuous-batching case, where every batch slot decodes at its own
    sequence offset and negative entries mark padding / inactive slots."""
    B, T = x.shape[0], x.shape[1]
    if jnp.ndim(cur_pos) == 0:
        return jnp.full((B, T), cur_pos, jnp.int32)
    pos = jnp.asarray(cur_pos, jnp.int32)
    if pos.ndim == 1:
        pos = pos[:, None]
    return jnp.broadcast_to(pos, (B, T))


def ring_scatter(buf, new, pos):
    """Write per-row ring-buffer entries: ``new[b, t]`` lands at slot
    ``pos[b, t] % cache_len`` of row b.  Entries with ``pos < 0`` (padding
    query rows / inactive batch slots) are DROPPED — the scatter targets an
    out-of-bounds slot, so the cache row is untouched.  buf: (B, S, ...);
    new: (B, T, ...); pos: (B, T) int32."""
    S = buf.shape[1]
    valid = pos >= 0
    slot = jnp.where(valid, jnp.mod(pos, S), S)      # S = OOB -> dropped
    bidx = jnp.broadcast_to(jnp.arange(buf.shape[0])[:, None], pos.shape)
    return buf.at[bidx, slot].set(new.astype(buf.dtype), mode="drop")


def decode_self_attention(w, x, cache, cfg, cur_pos, *, window: int = 0,
                          rope: bool = True):
    """One decode step.  x: (B,T,d) (T=1 historically); cache: dict from
    kv_cache_spec; cur_pos: scalar int32 — current absolute position (same
    for the batch) — or per-row (B,)/(B,T) positions (continuous batching:
    each slot at its own offset; negative = masked padding, no write).

    The new k/v is written at ``pos % cache_len`` (ring buffer: for
    full-context decode cache_len == seq so this is just pos)."""
    dt = x.dtype
    B = x.shape[0]
    if jnp.ndim(cur_pos) == 0 and x.shape[1] == 1:
        # historical scalar path, preserved byte-for-byte
        pos = jnp.full((B, 1), cur_pos, jnp.int32)
        q, k_new, v_new = qkv_project(w, x, cfg, pos, rope=rope)
        slot = _ring_index(cur_pos, cache["pos"].shape[1])
        k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
        cpos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], pos, slot,
                                                   axis=1)
    else:
        pos = decode_positions(x, cur_pos)
        # rope at clamped positions: padding rows are masked out anyway,
        # and valid rows have pos >= 0 so the clamp is the identity there
        q, k_new, v_new = qkv_project(w, x, cfg, jnp.maximum(pos, 0),
                                      rope=rope)
        k = ring_scatter(cache["k"], k_new, pos)
        v = ring_scatter(cache["v"], v_new, pos)
        cpos = ring_scatter(cache["pos"], pos, pos)
    if cfg.grouped_decode_attn:
        o = attend_grouped_decode(q, k.astype(dt), v.astype(dt), pos, cpos,
                                  causal=True, window=window)
    else:
        o = attend(q, expand_kv(k.astype(dt), cfg.n_q_per_kv),
                   expand_kv(v.astype(dt), cfg.n_q_per_kv), pos, cpos,
                   causal=True, window=window, chunk=0)
    new_cache = {"k": k, "v": v, "pos": cpos}
    return out_project(w, o), new_cache


def decode_mla_attention(w, x, cache, cfg, cur_pos, *, window: int = 0):
    """Absorbed-matmul MLA decode: scores against the *compressed* cache.

    q_nope (B,T,H,nd) is absorbed through w_uk into the lora space, so the
    per-step cost is O(S * (r + rd) * H) instead of O(S * H * (nd+rd)).
    ``cur_pos`` is a scalar (historical; T = 1) or per-row (B,)/(B,T)
    positions with negative entries masked (continuous batching)."""
    dt = x.dtype
    B = x.shape[0]
    H, nd, rd, r = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.kv_lora_rank
    scalar_pos = jnp.ndim(cur_pos) == 0 and x.shape[1] == 1
    if scalar_pos:
        pos = jnp.full((B, 1), cur_pos, jnp.int32)
        rope_pos = pos
    else:
        pos = decode_positions(x, cur_pos)
        rope_pos = jnp.maximum(pos, 0)
    q = jnp.einsum("bsd,dhe->bshe", x, w["wq"].astype(dt))
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, rope_pos, cfg.rope_theta)
    c_new = x @ w["w_dkv"].astype(dt)
    c_new = apply_norm({"scale": w["kv_norm"]}, c_new, cfg.norm_eps)
    kr_new = (x @ w["w_kr"].astype(dt))[:, :, None, :]
    kr_new = apply_rope(kr_new, rope_pos, cfg.rope_theta)[:, :, 0, :]
    if scalar_pos:
        slot = _ring_index(cur_pos, cache["pos"].shape[1])
        c = jax.lax.dynamic_update_slice_in_dim(
            cache["c"], c_new.astype(cache["c"].dtype), slot, axis=1)
        kr = jax.lax.dynamic_update_slice_in_dim(
            cache["kr"], kr_new.astype(cache["kr"].dtype), slot, axis=1)
        cpos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], pos, slot,
                                                   axis=1)
    else:
        c = ring_scatter(cache["c"], c_new, pos)
        kr = ring_scatter(cache["kr"], kr_new, pos)
        cpos = ring_scatter(cache["pos"], pos, pos)
    # absorb: q_abs = q_nope @ w_uk  -> (B,1,H,r)
    q_abs = jnp.einsum("bshe,rhe->bshr", q_nope, w["w_uk"].astype(dt))
    scale = 1.0 / math.sqrt(nd + rd)
    s = (jnp.einsum("bshr,btr->bhst", q_abs, c.astype(dt)) +
         jnp.einsum("bshe,bte->bhst", q_rope, kr.astype(dt))).astype(jnp.float32)
    s = s * scale
    allow = _mask(pos, cpos, True, window)
    s = jnp.where(allow, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx_c = jnp.einsum("bhst,btr->bshr", p.astype(dt), c.astype(dt))
    o = jnp.einsum("bshr,rhe->bshe", ctx_c, w["w_uv"].astype(dt))
    y = jnp.einsum("bshe,hed->bsd", o, w["wo"].astype(dt))
    return y, {"c": c, "kr": kr, "pos": cpos}
