"""LayeredModel: the layer-granular model API that the L2L engine executes.

A model is: ``prepare`` (embeddings / modality stubs) -> a sequence of
homogeneous **layer groups** (each scanned over a stacked ``(N, ...)`` param
tree) -> ``head_loss``.  Encoder-decoder models are two groups connected by a
``transition`` that turns the encoder output into the decoder's cross-
attention memory.

This factoring is exactly what L2L needs: the engine can relay weights
layer-by-layer (scan over the stacked axis), stash only group-boundary
activations, and recompute per-layer VJPs in the reverse scan.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, InputShape
from repro.models import blocks
from repro.models.blocks import Ctx
from repro.models.common import (ParamSpec, abstract, apply_norm, axes,
                                 materialize, norm_spec, softmax_xent,
                                 stack_specs)


class Group(NamedTuple):
    name: str
    n_layers: int
    spec: dict                       # one layer's ParamSpec tree
    apply: Callable                  # (w, x, mem, ctx) -> (x, aux)
    decode: Callable                 # (w, x, cache, mem, ctx) -> (x, cache)
    cache_spec: Callable             # (batch, live_seq) -> per-layer spec
    has_mem: bool = False
    is_encoder: bool = False         # not run during decode


def sinusoidal(positions, d, dtype):
    """positions: (B,S) -> (B,S,d) classic sin/cos embedding."""
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freq
    emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    if d % 2:
        emb = jnp.pad(emb, ((0, 0),) * (emb.ndim - 1) + ((0, 1),))
    return emb.astype(dtype)


class LayeredModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.groups = self._build_groups(cfg)

    # ------------------------------------------------------------------
    # group construction
    # ------------------------------------------------------------------
    def _build_groups(self, cfg) -> Tuple[Group, ...]:
        def G(name, n, spec, apply_fn, decode_fn, cache_fn, **kw):
            ap = lambda w, x, mem, ctx: apply_fn(w, x, mem, ctx, cfg)
            de = lambda w, x, c, mem, ctx: decode_fn(w, x, c, mem, ctx, cfg)
            cs = lambda b, live: cache_fn(cfg, b, live)
            return Group(name, n, spec, ap, de, cs, **kw)

        if cfg.family in ("dense", "vlm"):
            return (G("layers", cfg.n_layers, blocks.dense_spec(cfg),
                      blocks.dense_apply, blocks.dense_decode,
                      blocks.dense_cache_spec),)
        if cfg.family == "moe":
            gs = []
            if cfg.first_dense_layers:
                # deepseek-v2: layer 0 keeps MLA attention but a dense FFN;
                # dense_cache_spec -> kv_cache_spec branches on cfg.use_mla.
                gs.append(G("dense_layers", cfg.first_dense_layers,
                            blocks.moe_block_spec(cfg, dense_ffn=True),
                            blocks.moe_block_apply, blocks.moe_block_decode,
                            blocks.dense_cache_spec))
            gs.append(G("moe_layers", cfg.n_layers - cfg.first_dense_layers,
                        blocks.moe_block_spec(cfg),
                        blocks.moe_block_apply, blocks.moe_block_decode,
                        blocks.dense_cache_spec))
            return tuple(gs)
        if cfg.family == "hybrid":
            return (G("layers", cfg.n_layers, blocks.hybrid_spec(cfg),
                      blocks.hybrid_apply, blocks.hybrid_decode,
                      blocks.hybrid_cache_spec),)
        if cfg.family == "ssm":
            return (G("layers", cfg.n_layers, blocks.rwkv_spec(cfg),
                      blocks.rwkv_apply, blocks.rwkv_decode,
                      blocks.rwkv_cache_spec),)
        if cfg.family == "audio":
            enc = G("encoder", cfg.n_encoder_layers,
                    blocks.whisper_enc_spec(cfg), blocks.whisper_enc_apply,
                    blocks.whisper_dec_decode, blocks.whisper_dec_cache_spec,
                    is_encoder=True)
            dec = G("decoder", cfg.n_layers, blocks.whisper_dec_spec(cfg),
                    blocks.whisper_dec_apply, blocks.whisper_dec_decode,
                    blocks.whisper_dec_cache_spec, has_mem=True)
            return (enc, dec)
        raise ValueError(f"unknown family {cfg.family}")

    # ------------------------------------------------------------------
    # parameters
    # ------------------------------------------------------------------
    def param_specs(self) -> dict:
        cfg = self.cfg
        embed: dict = {}
        if cfg.family != "audio":
            embed["tok"] = ParamSpec((cfg.vocab_size, cfg.d_model),
                                     ("vocab", "d_model"), "embed")
        else:
            embed["tok"] = ParamSpec((cfg.vocab_size, cfg.d_model),
                                     ("vocab", "d_model"), "embed")
            embed["enc_ln_post"] = norm_spec(cfg)
        if cfg.is_vlm:
            embed["proj_w"] = ParamSpec((cfg.vit_dim, cfg.d_model),
                                        ("lora", "d_model"))
            embed["proj_b"] = ParamSpec((cfg.d_model,), ("d_model",), "zeros")
        head: dict = {"ln_f": norm_spec(cfg)}
        if not cfg.tie_embeddings:
            head["out"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                    ("d_model", "vocab"))
        groups = tuple(stack_specs(g.spec, g.n_layers) for g in self.groups)
        return {"embed": embed, "head": head, "groups": groups}

    def init_params(self, rng, dtype=None):
        dtype = dtype or jnp.dtype(self.cfg.param_dtype)
        return materialize(self.param_specs(), rng, dtype)

    def abstract_params(self, dtype=None):
        dtype = dtype or jnp.dtype(self.cfg.param_dtype)
        return abstract(self.param_specs(), dtype)

    def param_axes(self):
        return axes(self.param_specs())

    # ------------------------------------------------------------------
    # embedding / transitions / head
    # ------------------------------------------------------------------
    def _dtype(self):
        return jnp.dtype(self.cfg.dtype)

    def prepare(self, static, batch) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
        """-> (x0 for group 0, mem for group 0 (None))."""
        cfg = self.cfg
        dt = self._dtype()
        emb = static["embed"]
        if cfg.family == "audio":
            frames = batch["frames"].astype(dt)          # (B,nf,d) stub
            B, nf, _ = frames.shape
            pos = jnp.broadcast_to(jnp.arange(nf, dtype=jnp.int32), (B, nf))
            return frames + sinusoidal(pos, cfg.d_model, dt), None
        toks = batch["tokens"]
        x = jnp.take(emb["tok"], toks, axis=0).astype(dt)
        if cfg.is_vlm:
            p = batch["patches"].astype(dt) @ emb["proj_w"].astype(dt) \
                + emb["proj_b"].astype(dt)
            x = jnp.concatenate([p, x], axis=1)
        return x, None

    def transition_x(self, g: int, static, x_prev, batch):
        """Input activations of group g, given the output of group g-1.

        The identity for homogeneous-stream group changes (deepseek
        dense->moe); for whisper the decoder input is built from the target
        tokens (independent of x_prev — its gradient path to the encoder
        goes through ``transition_mem``)."""
        cfg = self.cfg
        dt = self._dtype()
        if cfg.family != "audio":
            return x_prev
        toks = batch["tokens"]
        B, S = toks.shape
        x = jnp.take(static["embed"]["tok"], toks, axis=0).astype(dt)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        return x + sinusoidal(pos, cfg.d_model, dt)

    def transition_mem(self, g: int, static, x_prev, batch):
        """Cross-attention memory of group g (None unless has_mem)."""
        cfg = self.cfg
        if not self.groups[g].has_mem:
            return None
        return apply_norm(static["embed"]["enc_ln_post"], x_prev,
                          cfg.norm_eps)

    def transition(self, g: int, static, x_prev, batch):
        return (self.transition_x(g, static, x_prev, batch),
                self.transition_mem(g, static, x_prev, batch))

    def head_loss(self, static, x, batch):
        """-> (loss_sum, weight_sum, aux_metrics). Caller normalizes."""
        cfg = self.cfg
        x = apply_norm(static["head"]["ln_f"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            w = static["embed"]["tok"].astype(x.dtype).T
        else:
            w = static["head"]["out"].astype(x.dtype)
        logits = x @ w
        if cfg.logit_soft_cap > 0:
            c = cfg.logit_soft_cap
            logits = c * jnp.tanh(logits / c)
        targets, mask = batch["targets"], batch["mask"]
        if cfg.is_vlm:  # x covers patches+tokens; loss only on token positions
            logits = logits[:, cfg.n_patches:, :]
        loss_sum, wsum = softmax_xent(logits, targets, mask)
        return loss_sum, wsum

    # ------------------------------------------------------------------
    # context builders
    # ------------------------------------------------------------------
    def train_ctx(self, batch, group: Group) -> Ctx:
        cfg = self.cfg
        if group.is_encoder:
            B, nf = batch["frames"].shape[:2]
            pos = jnp.broadcast_to(jnp.arange(nf, dtype=jnp.int32), (B, nf))
            return Ctx(positions=pos, causal=False)
        if cfg.family == "audio":
            B, S = batch["tokens"].shape
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
            mp = jnp.broadcast_to(jnp.arange(cfg.n_frames, dtype=jnp.int32),
                                  (B, cfg.n_frames))
            return Ctx(positions=pos, mem_positions=mp, causal=True)
        B, S = batch["tokens"].shape
        if cfg.is_vlm:
            S = S + cfg.n_patches
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        return Ctx(positions=pos, causal=True, window=cfg.sliding_window)

    def decode_ctx(self, cur_pos, window: int = 0) -> Ctx:
        w = window if window else self.cfg.sliding_window
        return Ctx(cur_pos=cur_pos, window=w, causal=True)

    # ------------------------------------------------------------------
    # decode embedding / head
    # ------------------------------------------------------------------
    def decode_embed(self, static, token, cur_pos):
        """token: (B,T) (T=1 historically) -> x (B,T,d).  ``cur_pos`` is a
        scalar or per-row (B,)/(B,T) position array (continuous batching);
        negative entries mark padding rows (their embeddings are computed
        but masked downstream)."""
        cfg = self.cfg
        dt = self._dtype()
        x = jnp.take(static["embed"]["tok"], token, axis=0).astype(dt)
        if cfg.family == "audio":
            from repro.models.attention import decode_positions
            pos = jnp.maximum(decode_positions(x, cur_pos), 0)
            x = x + sinusoidal(pos, cfg.d_model, dt)
        return x

    def decode_logits(self, static, x):
        cfg = self.cfg
        x = apply_norm(static["head"]["ln_f"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            w = static["embed"]["tok"].astype(x.dtype).T
        else:
            w = static["head"]["out"].astype(x.dtype)
        logits = x @ w
        if cfg.logit_soft_cap > 0:
            c = cfg.logit_soft_cap
            logits = c * jnp.tanh(logits / c)
        return logits

    def decode_groups(self):
        return tuple(g for g in self.groups if not g.is_encoder)

    # ------------------------------------------------------------------
    # full caches (stacked over layers per group)
    # ------------------------------------------------------------------
    def cache_specs(self, batch: int, live_seq: int):
        return tuple(stack_specs(g.cache_spec(batch, live_seq), g.n_layers)
                     for g in self.decode_groups())

    # ------------------------------------------------------------------
    # reference full forward (baseline engines + tests)
    # ------------------------------------------------------------------
    def full_loss(self, params, batch, remat: bool = False):
        static = {"embed": params["embed"], "head": params["head"]}
        x, mem = self.prepare(static, batch)
        aux_total = jnp.float32(0.0)
        for gi, group in enumerate(self.groups):
            if gi > 0:
                x, mem = self.transition(gi, static, x, batch)
            ctx = self.train_ctx(batch, group)
            body = lambda h, w, _mem=mem, _ctx=ctx, _g=group: \
                _g.apply(w, h, _mem, _ctx)
            if remat:
                body = jax.checkpoint(body)
            def scan_body(h, w):
                h2, aux = body(h, w)
                return h2, aux
            x, auxs = jax.lax.scan(scan_body, x, params["groups"][gi])
            aux_total = aux_total + auxs.sum()
        loss_sum, wsum = self.head_loss(static, x, batch)
        loss = loss_sum / jnp.maximum(wsum, 1.0) + aux_total
        return loss, (loss_sum, wsum, aux_total)


# ---------------------------------------------------------------------------
# Batch specs (ShapeDtypeStruct stand-ins come from launch/dryrun via these)
# ---------------------------------------------------------------------------
def batch_spec(cfg: ModelConfig, shape: InputShape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"token": ParamSpec((B, 1), ("batch", None), "zeros")}
    if shape.kind == "prefill":
        spec = {"tokens": ParamSpec(
            (B, S if not cfg.is_vlm else S - cfg.n_patches),
            ("batch", "seq"), "zeros")}
        if cfg.family == "audio":
            spec["frames"] = ParamSpec((B, cfg.n_frames, cfg.d_model),
                                       ("batch", "seq", "d_model"), "zeros")
        if cfg.is_vlm:
            spec["patches"] = ParamSpec((B, cfg.n_patches, cfg.vit_dim),
                                        ("batch", "seq", "d_model"), "zeros")
        return spec
    if cfg.family == "audio":
        return {
            "frames": ParamSpec((B, cfg.n_frames, cfg.d_model),
                                ("batch", "seq", "d_model"), "zeros"),
            "tokens": ParamSpec((B, S), ("batch", "seq"), "zeros"),
            "targets": ParamSpec((B, S), ("batch", "seq"), "zeros"),
            "mask": ParamSpec((B, S), ("batch", "seq"), "ones"),
        }
    spec = {
        "tokens": ParamSpec((B, S if not cfg.is_vlm else S - cfg.n_patches),
                            ("batch", "seq"), "zeros"),
        "targets": ParamSpec((B, S if not cfg.is_vlm else S - cfg.n_patches),
                             ("batch", "seq"), "zeros"),
        "mask": ParamSpec((B, S if not cfg.is_vlm else S - cfg.n_patches),
                          ("batch", "seq"), "ones"),
    }
    if cfg.is_vlm:
        spec["patches"] = ParamSpec((B, cfg.n_patches, cfg.vit_dim),
                                    ("batch", "seq", "d_model"), "zeros")
    return spec


def batch_dtypes(cfg: ModelConfig, shape: InputShape) -> dict:
    spec = batch_spec(cfg, shape)
    out = {}
    for k, s in spec.items():
        if k in ("tokens", "targets", "token"):
            out[k] = jnp.int32
        elif k == "mask":
            out[k] = jnp.float32
        else:
            out[k] = jnp.dtype(cfg.dtype)
    return out
