"""Public execution-engine facade.

Usage::

    from repro import engine as engines

    eng = engines.create("l2l-p", model_cfg, exec_cfg, optimizer=adam())
    state = eng.init(rng)
    state, metrics = eng.train_step(state, batch)

See ``repro.engine.engine`` for the Engine API and the registered
schedules ("baseline", "l2l", "l2l-p").
"""
from repro.engine.engine import (BaselineEngine, Engine, L2LEngine,
                                 L2LPEngine)
from repro.engine.registry import available, create, get, register
from repro.engine.state import TrainState

__all__ = ["Engine", "BaselineEngine", "L2LEngine", "L2LPEngine",
           "TrainState", "available", "create", "get", "register"]
