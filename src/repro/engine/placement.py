"""EPS placement construction for engines.

Single-device (tests/benchmarks) placements come straight from
``repro.core.eps.make_placements``; for a mesh this derives the
per-layer-slice PartitionSpecs from the model's param specs (the logic
that used to live in ``repro.launch.build.make_placements_for``) and
hands them to the same ``make_placements``.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.core.eps import EPSPlacements, make_placements, pspecs_like


def placements_for(model, exec_cfg, mesh=None, rules=None,
                   optimizer=None) -> EPSPlacements:
    """Build the per-group weight/opt/stash placements for one engine.

    With no mesh this is the single-device two-tier placement (or no-ops
    when the backend drops memory-space transfers / streaming is off).
    With a mesh, per-layer-slice pspecs are derived from the model's param
    specs and the sharding ``rules`` (defaulting to the production train
    rules for the config).

    The same per-slice placements serve every relay schedule: the
    unified executor (``repro.core.relay``) builds its
    ``prefetch_depth + 1``-slot ring and ``layers_per_relay``-layer
    group slots over them (grouped slots fetch through
    ``Placement.dev_grouped``, which shifts the layer-slice pspecs one
    dim right of the leading stop axis), so nothing here grows with G or
    k — only how many slices are in HBM at once.

    With ``exec_cfg.pack_params`` the relayed trees are ``packing.Packed``
    flat buffers (one leaf per dtype segment), which cannot reuse the
    per-leaf tensor-parallel specs: packed relay buffers are placed
    replicated over the model axes (P() broadcast).  Data-parallel meshes
    are unaffected; on model-parallel meshes packing trades the sharded
    weight residency for one-DMA-per-layer relays (sharded packing —
    per-shard segments — is future work).
    """
    if mesh is None:
        return make_placements(exec_cfg, len(model.groups))

    from repro.distributed import sharding as shd
    from repro.models.common import abstract
    from repro.optim import adam

    if rules is None:
        rules = shd.make_rules(model.cfg, mesh, kind="train")
    if exec_cfg.pack_params:
        n = len(model.groups)
        return make_placements(exec_cfg, n, mesh=mesh,
                               weight_pspecs=(P(),) * n,
                               opt_pspecs=(P(),) * n,
                               stash_pspec=P(None, rules.get("batch")))
    optimizer = optimizer or adam()
    slice_pspecs = shd.layer_slice_pspecs(model, mesh, rules)
    opt_slice_pspecs = []
    for gi, g in enumerate(model.groups):
        layer_abs = abstract(g.spec)
        opt_abs = jax.eval_shape(optimizer.init, layer_abs)
        opt_slice_pspecs.append(pspecs_like(slice_pspecs[gi], opt_abs))
    return make_placements(exec_cfg, len(model.groups), mesh=mesh,
                           weight_pspecs=slice_pspecs,
                           opt_pspecs=opt_slice_pspecs,
                           stash_pspec=P(None, rules.get("batch")))
