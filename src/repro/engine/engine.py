"""The Engine facade — the one public way to run any execution schedule.

An Engine owns a model, an ExecutionConfig, an optimizer and the EPS
placements, and exposes the full lifecycle over a single ``TrainState``
pytree::

    from repro import engine as engines

    eng = engines.create("l2l-p", get_config("bert-large", "smoke"),
                         ExecutionConfig(n_microbatches=4))
    state = eng.init(jax.random.PRNGKey(0))
    state, metrics = eng.train_step(state, batch)     # lazily jitted
    logits = eng.prefill(state.params, batch)
    eng.save(ckpt_dir, state)

Registered schedules:

* ``baseline`` — Algorithms 1/2 (conventional execution; microbatch loop
  inner, monolithic update).
* ``l2l``      — Algorithm 3 (layer-major relay, trailing optimizer).
* ``l2l-p``    — Algorithm 4 (layer-major relay, eager per-layer
  optimizer overlapped with the backward).

The ``repro.core`` kernels (``l2l``/``baseline``/``decode``) stay
internal: every consumer — launchers, benchmarks, examples, tests — goes
through this facade, so new schedules (pipelined, multi-device relay)
only have to subclass ``Engine`` and ``@register`` themselves.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro.checkpoint import io as ckpt_io
from repro.configs.base import ModelConfig
from repro.core import baseline as _baseline, decode as _decode, l2l as _l2l
from repro.core import packing
from repro.core.memory_model import MemoryReport, estimate
from repro.core.schedule import ExecutionConfig
from repro.engine.placement import placements_for
from repro.engine.registry import register
from repro.engine.state import TrainState
from repro.models.model import LayeredModel
from repro.optim import Optimizer, adam


class Engine:
    """Base facade: lifecycle + lazy jit over a schedule's kernels.

    Subclasses implement ``_make_step_kernel``/``_make_grads_kernel``/
    ``_init_opt_legacy`` and set ``name``/``memory_mode``.
    """
    name = "base"
    memory_mode = "baseline"

    def __init__(self, model, exec_cfg: Optional[ExecutionConfig] = None, *,
                 optimizer: Optional[Optimizer] = None, mesh=None,
                 rules=None, placements=None, donate: bool = True):
        if isinstance(model, ModelConfig):
            model = LayeredModel(model)
        self.model = model
        self.exec_cfg = self._normalize_cfg(exec_cfg or ExecutionConfig())
        self.optimizer = optimizer or adam()
        self.mesh = mesh
        self._rules = rules
        self._placements = placements
        self._donate = donate
        self._fns: dict = {}        # lazily built kernels / jitted wrappers

    # -- schedule-specific hooks (override in subclasses) -------------------
    def _normalize_cfg(self, exec_cfg: ExecutionConfig) -> ExecutionConfig:
        return exec_cfg

    def _make_step_kernel(self):
        raise NotImplementedError

    def _make_grads_kernel(self):
        raise NotImplementedError

    def _init_opt_legacy(self, params) -> dict:
        raise NotImplementedError

    # -- placements ---------------------------------------------------------
    @property
    def placements(self):
        if self._placements is None:
            self._placements = placements_for(
                self.model, self.exec_cfg, mesh=self.mesh, rules=self._rules,
                optimizer=self.optimizer)
        return self._placements

    # -- storage tier (ExecutionConfig.tiers = 3) ---------------------------
    @property
    def tier(self):
        """The live disk-tier adapter (``core.tierstore.TierChain``), or
        None for the historical two-tier placement.  Built lazily from
        ``placements.disk``; the segment store lives in
        ``exec_cfg.tier_dir`` (a fresh temp dir when unset).  Around
        every jitted call the chain re-materializes the demoted cold row
        tail of each layer group and writes updated rows back through
        verified, crash-consistent segment files — byte-identical to the
        host-only relay (tests/test_tierstore.py)."""
        spec = self.placements.disk
        if spec is None:
            return None
        if "tier" not in self._fns:
            import tempfile
            from repro.core import tierstore
            root = spec.directory or tempfile.mkdtemp(prefix="eps-tier-")
            store = tierstore.SegmentStore(
                root, retries=spec.retries, backoff_s=spec.backoff_s)
            self._fns["tier"] = tierstore.TierChain(
                store, host_budget=spec.host_budget,
                layers_per_relay=self.exec_cfg.layers_per_relay,
                prefetch_depth=self.exec_cfg.prefetch_depth)
        return self._fns["tier"]

    def _materialize(self, params):
        """Params with demoted groups re-read from the segment store
        (identity-cached inside the chain) — every read verified, retried
        on transient errors, quarantined + rebuilt on checksum failure."""
        tier = self.tier
        return params if tier is None else tier.materialize_params(params)

    # -- packed relay (ExecutionConfig.pack_params) -------------------------
    def _relay_params(self, params):
        """Params in the layout the relay kernels expect: with
        ``pack_params`` the stacked layer groups are coalesced into
        per-dtype flat buffers (``core.packing``) so each EPS relay is one
        large DMA per layer.  Idempotent — already-packed groups pass
        through, so callers may hand either layout to ``grads`` /
        ``prefill`` / ``decode_*``.  The last conversion is cached by
        object identity: a serving loop that calls ``decode_step`` with
        the same unpacked params every token packs once, not per token
        (params trees are never mutated in place anywhere in this repo)."""
        if not self.exec_cfg.pack_params:
            return params
        if all(packing.is_packed(g) for g in params["groups"]):
            return params
        cached = self._fns.get("_pack_cache")
        if cached is not None and cached[0] is params:
            return cached[1]
        packed = packing.pack_params(params)
        self._fns["_pack_cache"] = (params, packed)
        return packed

    # -- state lifecycle ----------------------------------------------------
    def init(self, rng) -> TrainState:
        """Materialize parameters + optimizer state from a PRNG key.
        With the storage tier enabled the fresh state is adopted by the
        TierChain: segments written to the store, cold rows demoted."""
        params = self._relay_params(self.model.init_params(rng))
        state = TrainState.from_legacy(params, self._init_opt_legacy(params))
        if self.tier is not None:
            state = self.tier.adopt(state, step=0)
        return state

    def abstract_state(self) -> TrainState:
        """ShapeDtypeStruct TrainState (for lowering / restore targets)."""
        params_abs = jax.eval_shape(self._relay_params,
                                    self.model.abstract_params())
        opt_abs = jax.eval_shape(self._init_opt_legacy, params_abs)
        return TrainState.from_legacy(params_abs, opt_abs)

    def state_fingerprint(self) -> str:
        """Stable identity of the on-disk state layout: a checkpoint is
        only restorable into the same (arch, depth, width, vocab,
        optimizer) tuple.  Relay knobs (pack/group/prefetch/K) are
        deliberately absent — checkpoints interchange across them."""
        cfg = self.model.cfg
        return (f"{cfg.name}:L{cfg.n_layers}:d{cfg.d_model}:"
                f"v{cfg.vocab_size}:opt={self.optimizer.name}")

    def save(self, directory: str, state: TrainState,
             step: Optional[int] = None, prefix: str = "ckpt",
             keep_last: int = 0) -> str:
        """Checkpoints are always written in the UNPACKED pytree layout —
        a packed engine's flat buffers are converted through their
        PackSpecs first, so checkpoints interchange freely between
        pack_params on/off (tests/test_packing.py).  The write is
        crash-consistent (staged + fsynced + atomically renamed, crc32
        per array in the manifest — ``checkpoint.io``); ``keep_last=N``
        prunes all but the N newest snapshots after the save."""
        if self.tier is not None:
            # checkpoints hold the FULL state; also make this directory
            # the quarantine-rebuild source for the segment store
            state = self.tier.stage_in(state)
            self.tier.attach_checkpoints(directory, prefix, self)
        step = int(state.step) if step is None else int(step)
        params, opt = state.params, state.legacy_opt()
        if self.exec_cfg.pack_params:
            opt = packing.unpack_opt_state(opt, params)
            params = packing.unpack_params(params)
        return ckpt_io.save_train_state(
            directory, params, opt, step, prefix=prefix,
            keep_last=keep_last, fingerprint=self.state_fingerprint())

    def restore(self, directory: str, step: Optional[int] = None,
                like: Optional[TrainState] = None, prefix: str = "ckpt"):
        """Returns (TrainState, step).  ``like`` defaults to the engine's
        abstract state; packed engines restore the unpacked checkpoint
        layout and re-pack.  With ``step=None`` the newest snapshot that
        passes crc32 + fingerprint verification is used — a corrupt or
        half-written snapshot falls back to the previous good one."""
        like = like if like is not None else self.abstract_state()
        like_p, like_o = like.params, like.legacy_opt()
        if self.exec_cfg.pack_params:
            like_o = jax.eval_shape(packing.unpack_opt_state, like_o, like_p)
            like_p = jax.eval_shape(packing.unpack_params, like_p)
        params, opt, step = ckpt_io.restore_train_state(
            directory, like_p, like_o, step=step, prefix=prefix,
            fingerprint=self.state_fingerprint())
        if self.exec_cfg.pack_params:
            params = packing.pack_params(params)
            opt = packing.pack_opt_state(opt, params)
        state = TrainState.from_legacy(params, opt)
        if self.tier is not None:
            state = self.tier.adopt(state, step=step)
            self.tier.attach_checkpoints(directory, prefix, self)
        return state, step

    # -- training -----------------------------------------------------------
    # -- runtime-dynamic depth ---------------------------------------------
    def _depth_operand(self, n_layers):
        """The traced int32 depth operand for a dynamic-depth call
        (defaults to the capacity depth); asserts the knob elsewhere."""
        import jax.numpy as jnp
        if not self.exec_cfg.dynamic_depth:
            assert n_layers is None, \
                "n_layers needs ExecutionConfig.dynamic_depth"
            return None
        cap = sum(g.n_layers for g in self.model.groups)
        n = cap if n_layers is None else int(n_layers)
        assert 0 <= n <= cap, f"n_layers {n} exceeds capacity {cap}"
        return jnp.asarray(n, jnp.int32)

    @property
    def step_fn(self):
        """Unjitted (state, batch[, n_layers]) -> (state, metrics) — for
        callers that manage jit/shardings themselves (dry-run lowering).
        With ``dynamic_depth`` the traced ``n_layers`` operand is part of
        the signature: one compiled program serves every depth."""
        if "step_fn" not in self._fns:
            kernel = self._make_step_kernel()

            if self.exec_cfg.dynamic_depth:
                def step(state: TrainState, batch, n_layers):
                    new_p, new_o, metrics = kernel(
                        state.params, state.legacy_opt(), batch, n_layers)
                    return TrainState.from_legacy(new_p, new_o), metrics
            else:
                def step(state: TrainState, batch):
                    new_p, new_o, metrics = kernel(
                        state.params, state.legacy_opt(), batch)
                    return TrainState.from_legacy(new_p, new_o), metrics

            self._fns["step_fn"] = step
        return self._fns["step_fn"]

    def train_step(self, state: TrainState, batch, n_layers=None):
        """One optimizer step: (state, batch) -> (state, metrics).  With
        the storage tier the demoted cold rows are staged in from the
        segment store before the jitted step and the updated rows staged
        back out (verified, crash-consistent) after it.  With
        ``dynamic_depth``, ``n_layers`` (<= capacity, default capacity)
        picks the runtime depth without retracing."""
        if "train_step" not in self._fns:
            donate = (0,) if self._donate else ()
            self._fns["train_step"] = jax.jit(self.step_fn,
                                              donate_argnums=donate)
        tier = self.tier
        if tier is not None:
            state = tier.stage_in(state)
        n_op = self._depth_operand(n_layers)
        args = (state, batch) if n_op is None else (state, batch, n_op)
        state, metrics = self._fns["train_step"](*args)
        if tier is not None:
            state = tier.stage_out(state)
        return state, metrics

    # -- gradients (no update) ---------------------------------------------
    @property
    def grads_fn(self):
        """Unjitted (params, batch) -> (loss, grads)."""
        if "grads_fn" not in self._fns:
            self._fns["grads_fn"] = self._make_grads_kernel()
        return self._fns["grads_fn"]

    def grads(self, state_or_params, batch, n_layers=None):
        if "grads" not in self._fns:
            self._fns["grads"] = jax.jit(self.grads_fn)
        params = getattr(state_or_params, "params", state_or_params)
        n_op = self._depth_operand(n_layers)
        args = () if n_op is None else (n_op,)
        return self._fns["grads"](
            self._relay_params(self._materialize(params)), batch, *args)

    # -- inference ----------------------------------------------------------
    @property
    def prefill_fn(self):
        """Unjitted (params, batch) -> last-token logits (B, vocab)."""
        if "prefill_fn" not in self._fns:
            self._fns["prefill_fn"] = _l2l.make_prefill_fn(
                self.model, self.exec_cfg, self.placements)
        return self._fns["prefill_fn"]

    def prefill(self, state_or_params, batch, n_layers=None):
        if "prefill" not in self._fns:
            self._fns["prefill"] = jax.jit(self.prefill_fn)
        params = getattr(state_or_params, "params", state_or_params)
        n_op = self._depth_operand(n_layers)
        args = () if n_op is None else (n_op,)
        return self._fns["prefill"](
            self._relay_params(self._materialize(params)), batch, *args)

    @property
    def decode_step_fn(self):
        """Unjitted (params, caches, token, cur_pos) -> (logits, caches)."""
        if "decode_step_fn" not in self._fns:
            self._fns["decode_step_fn"] = _decode.make_serve_step(
                self.model, self.exec_cfg, self.placements)
        return self._fns["decode_step_fn"]

    def decode_init(self, state_or_params, tokens, live_seq: int,
                    frames=None, n_layers=None):
        """Prefill the decode caches from a prompt.
        Returns (caches, last_logits)."""
        params = getattr(state_or_params, "params", state_or_params)
        return _decode.prefill(self.model,
                               self._relay_params(self._materialize(params)),
                               tokens, live_seq,
                               exec_cfg=self.exec_cfg, frames=frames,
                               n_layers=n_layers)

    def decode_step(self, state_or_params, caches, token, cur_pos,
                    n_layers=None):
        if "decode_step" not in self._fns:
            self._fns["decode_step"] = jax.jit(self.decode_step_fn)
        params = getattr(state_or_params, "params", state_or_params)
        n_op = self._depth_operand(n_layers)
        args = () if n_op is None else (n_op,)
        return self._fns["decode_step"](
            self._relay_params(self._materialize(params)), caches,
            token, cur_pos, *args)

    # -- continuous-batching serve ------------------------------------------
    def serve_session(self, state_or_params, serve_cfg=None, **kw):
        """Open a continuous-batching serve session (``repro.serve``):
        a paged-KV ServeEngine over this engine's model, relay knobs and
        placements.  ``serve_cfg`` is a ``ServeConfig``; keyword shape
        knobs (max_batch, page_size, ...) build one when omitted::

            srv = eng.serve_session(params, max_batch=8, max_seq=64)
            srv.submit(prompt_ids, max_new=32)
            done = srv.run()
        """
        from repro.serve.engine import ServeConfig, ServeEngine
        params = self._materialize(
            getattr(state_or_params, "params", state_or_params))
        if serve_cfg is None:
            serve_cfg = ServeConfig(**kw)
        return ServeEngine(self, params, serve_cfg)

    def serve_memory_estimate(self, serve_cfg, **kw) -> MemoryReport:
        """Analytic serve-mode byte split (paged pool + slot state +
        relay transit) for this engine's knobs at a ServeConfig shape."""
        from repro.core.memory_model import estimate_serve
        kw.setdefault("weight_stream", self.exec_cfg.weight_stream)
        kw.setdefault("prefetch_depth", self.exec_cfg.prefetch_depth)
        kw.setdefault("pack_params", self.exec_cfg.pack_params)
        kw.setdefault("layers_per_relay", self.exec_cfg.layers_per_relay)
        kw.setdefault("transport", self.exec_cfg.transport)
        return estimate_serve(
            self.model, max_batch=serve_cfg.max_batch,
            page_size=serve_cfg.page_size, n_pages=serve_cfg.n_pages,
            max_seq=serve_cfg.max_seq,
            prefill_chunk=serve_cfg.prefill_chunk, **kw)

    # -- analysis -----------------------------------------------------------
    def memory_estimate(self, *, batch: int, seq: int,
                        **kw) -> MemoryReport:
        """Analytic two-tier device/EPS byte split (paper eqs. 1-4) for
        this engine's schedule at the given shape."""
        kw.setdefault("n_microbatches", self.exec_cfg.n_microbatches)
        kw.setdefault("offload_stash", self.exec_cfg.offload_stash)
        kw.setdefault("stash_every", self.exec_cfg.stash_every)
        kw.setdefault("segment_scan", self.exec_cfg.segment_scan)
        kw.setdefault("prefetch_depth", self.exec_cfg.prefetch_depth)
        kw.setdefault("pack_params", self.exec_cfg.pack_params)
        kw.setdefault("layers_per_relay", self.exec_cfg.layers_per_relay)
        kw.setdefault("tiers", self.exec_cfg.tiers)
        kw.setdefault("host_budget", self.exec_cfg.host_budget_bytes)
        kw.setdefault("transport", self.exec_cfg.transport)
        return estimate(self.model, batch=batch, seq=seq,
                        mode=self.memory_mode, **kw)

    def describe(self) -> dict:
        return {"engine": self.name,
                "arch": self.model.cfg.name,
                "exec": dataclasses.asdict(self.exec_cfg)}


# ===========================================================================
# Registered schedules
# ===========================================================================
@register("baseline")
class BaselineEngine(Engine):
    """Algorithms 1/2: conventional execution; Alg 2 (gradient
    accumulation) when ``n_microbatches > 1``."""
    name = "baseline"

    def _normalize_cfg(self, exec_cfg):
        # conventional execution has no relay — the packed flat-buffer
        # layout, the pallas copy transport and the relay's runtime-
        # dynamic depth gating are L2L concerns; the baseline kernels
        # speak pytrees and never issue relay copies
        return dataclasses.replace(exec_cfg, pack_params=False,
                                   transport="xla", dynamic_depth=False)

    @property
    def memory_mode(self):
        return "baseline_remat" if self.exec_cfg.remat else "baseline"

    def _make_step_kernel(self):
        return _baseline.make_train_step(self.model, self.optimizer,
                                         self.exec_cfg)

    def _make_grads_kernel(self):
        return _baseline.make_grads_fn(self.model, self.exec_cfg)

    def _init_opt_legacy(self, params):
        return _baseline.init_opt_state(self.optimizer, params)


class _L2LBase(Engine):
    def _make_step_kernel(self):
        return _l2l.make_train_step(self.model, self.optimizer,
                                    self.exec_cfg, self.placements)

    def _make_grads_kernel(self):
        return _l2l.make_grads_fn(self.model, self.exec_cfg,
                                  self.placements)

    def _init_opt_legacy(self, params):
        return _l2l.init_opt_state(self.optimizer, params, self.exec_cfg)


@register("l2l")
class L2LEngine(_L2LBase):
    """Algorithm 3: layer-major relay; gradients shipped to the EPS and
    applied in a trailing layer loop."""
    name = "l2l"
    memory_mode = "l2l"

    def _normalize_cfg(self, exec_cfg):
        return dataclasses.replace(exec_cfg, eager_optimizer=False)


@register("l2l-p")
class L2LPEngine(_L2LBase):
    """Algorithm 4 (L2L-p): the optimizer for layer l runs inside the
    reverse scan, overlapping the backward of layer l-1, with per-layer
    eager gradient reduction."""
    name = "l2l-p"
    memory_mode = "l2l_p"

    def _normalize_cfg(self, exec_cfg):
        return dataclasses.replace(exec_cfg, eager_optimizer=True)
