"""TrainState — the one training-state pytree shared by every Engine.

Every execution schedule (baseline Alg 1/2, L2L Alg 3, L2L-p Alg 4)
consumes and produces the same state: parameters, per-subtree optimizer
slots, the step counter, and (when AMP is on) the dynamic loss scale.
The core kernels in ``repro.core`` predate this dataclass and speak a flat
dict (``{"step", "embed", "head", "groups"[, "loss_scale"]}``);
``legacy_opt``/``from_legacy`` convert at the engine boundary so the
kernels stay untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax


@dataclasses.dataclass
class TrainState:
    """Pytree of everything a training step consumes and produces.

    ``params``      — model parameters ({"embed", "head", "groups"}).
    ``opt_state``   — optimizer slots mirroring params ({"embed", "head",
                      "groups"}), WITHOUT the step counter.
    ``step``        — scalar int32 update counter.
    ``loss_scale``  — {"scale", "good_steps"} when AMP is enabled, else None.

    With ``ExecutionConfig.pack_params`` the ``groups`` entries hold
    ``core.packing.Packed`` flat buffers (and ``{slot: Packed}`` for the
    optimizer) instead of per-leaf pytrees; both are ordinary pytree
    nodes, so this dataclass, jit donation and the legacy converters are
    layout-agnostic.  Checkpoints always use the unpacked layout — the
    conversion lives in ``Engine.save``/``restore``.
    """
    params: Any
    opt_state: Any
    step: Any
    loss_scale: Any = None

    _OPT_KEYS = ("embed", "head", "groups")

    def legacy_opt(self) -> dict:
        """The flat opt-state dict the ``repro.core`` kernels expect."""
        out = {"step": self.step, **{k: self.opt_state[k]
                                     for k in self._OPT_KEYS}}
        if self.loss_scale is not None:
            out["loss_scale"] = self.loss_scale
        return out

    @classmethod
    def from_legacy(cls, params, opt: dict) -> "TrainState":
        return cls(params=params,
                   opt_state={k: opt[k] for k in cls._OPT_KEYS},
                   step=opt["step"],
                   loss_scale=opt.get("loss_scale"))

    def replace(self, **kw) -> "TrainState":
        return dataclasses.replace(self, **kw)


jax.tree_util.register_dataclass(
    TrainState,
    data_fields=("params", "opt_state", "step", "loss_scale"),
    meta_fields=())
