"""Open registry of execution engines.

The three paper schedules register themselves on import of
``repro.engine`` ("baseline" = Alg 1/2, "l2l" = Alg 3, "l2l-p" = Alg 4);
future schedules (pipelined, multi-device relay, ...) plug in with the
same decorator without touching any caller::

    @register("my-schedule")
    class MyEngine(Engine):
        ...

    eng = engines.create("my-schedule", model_cfg, exec_cfg)
"""
from __future__ import annotations

from typing import Callable, Dict

_REGISTRY: Dict[str, Callable] = {}


def register(name: str) -> Callable:
    """Class/factory decorator: ``create(name, ...)`` will call it as
    ``factory(model, exec_cfg, **kwargs)``."""
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def available() -> list:
    return sorted(_REGISTRY)


def get(name: str) -> Callable:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; available engines: "
            f"{', '.join(available()) or '(none registered)'}") from None


def create(name: str, model, exec_cfg=None, **kwargs):
    """Build a registered Engine.

    ``model`` is a ModelConfig (a LayeredModel is built internally) or an
    already-built LayeredModel.  Keyword args are forwarded to the engine
    constructor (``optimizer=``, ``mesh=``, ``rules=``, ``placements=``,
    ``donate=``).
    """
    return get(name)(model, exec_cfg, **kwargs)
