"""Open registry of execution engines.

The three paper schedules register themselves on import of
``repro.engine`` ("baseline" = Alg 1/2, "l2l" = Alg 3, "l2l-p" = Alg 4);
future schedules (pipelined, multi-device relay, ...) plug in with the
same decorator without touching any caller::

    @register("my-schedule")
    class MyEngine(Engine):
        ...

    eng = engines.create("my-schedule", model_cfg, exec_cfg)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

_REGISTRY: Dict[str, Callable] = {}


def register(name: str) -> Callable:
    """Class/factory decorator: ``create(name, ...)`` will call it as
    ``factory(model, exec_cfg, **kwargs)``."""
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def available() -> list:
    return sorted(_REGISTRY)


def get(name: str) -> Callable:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; available engines: "
            f"{', '.join(available()) or '(none registered)'}") from None


def create(name: str, model, exec_cfg=None, *,
           exec_overrides: Optional[dict] = None, **kwargs):
    """Build a registered Engine.

    ``model`` is a ModelConfig (a LayeredModel is built internally) or an
    already-built LayeredModel.  ``exec_overrides`` patches fields onto
    ``exec_cfg`` (or the default config) without the caller rebuilding a
    frozen ExecutionConfig — e.g. ``exec_overrides={"prefetch_depth": 2}``
    for a deeper relay prefetch ring, ``{"pack_params": True}`` for the
    packed flat-buffer relay + fused optimizer,
    ``{"layers_per_relay": 4}`` to relay four stacked layers per stop
    (one DMA covers the group; device weight footprint G·(1 + k) layer
    slots), or ``{"stash_every": 4}`` for the constant-memory stash
    (checkpoint every 4th layer boundary — ceil(N/4) stashed boundaries
    instead of N — and recompute the rest during the reverse relay by
    re-streaming each segment forward), or
    ``{"tiers": 3, "host_budget_bytes": B}`` for the storage-tier EPS
    (the cold stacked-state tail beyond B bytes lives in a verified
    on-disk SegmentStore and is staged around every jitted call —
    bit-identical, self-healing from checkpoints), or
    ``{"transport": "pallas"}`` to move every relay slot through the
    double-buffered ``kernels/relay_copy`` DMA pipeline instead of
    scan-boundary ``device_put``s (overlap enforced by kernel
    semaphores; bit-identical).  Remaining keyword
    args are forwarded
    to the engine constructor (``optimizer=``, ``mesh=``, ``rules=``,
    ``placements=``, ``donate=``).
    """
    if exec_overrides:
        from repro.core.schedule import ExecutionConfig
        exec_cfg = dataclasses.replace(exec_cfg or ExecutionConfig(),
                                       **exec_overrides)
    return get(name)(model, exec_cfg, **kwargs)
