"""L2L (layer-to-layer) execution engine — Algorithms 3 and 4 of the paper.

The loop inversion is the whole trick: the LAYER loop is outer, the
MICROBATCH loop is inner.  In JAX the outer loop is a relay scan over the
group's stacked ``(N_layers, ...)`` parameters — when those live in
``pinned_host`` (ExecutionConfig.weight_stream) each relay stop is a
host->HBM copy, i.e. the EPS feeding the device one slot at a time.

Forward (Alg 3 lines 2-6):   for l in layers: for u in microbatches:
    run layer l on microbatch u; stash ONLY the layer-boundary activation
    (optionally offloaded to pinned_host — eq. (4) constant memory).

Backward (Alg 3 lines 7-11 / Alg 4): reverse relay over layers; per
microbatch, RECOMPUTE the layer forward via ``jax.vjp`` from the stashed
boundary input (the paper's rematerialization), accumulate (dw, dx, dmem).

Constant-memory stash (``ExecutionConfig.stash_every`` = K > 1): the
forward stashes only the boundaries at layer indices = 0 (mod K) within
each group — ceil(N/K) checkpoints instead of N, so even the offloaded
stash stops growing with depth.  The backward walks the K-segments in
reverse; on arriving at a segment it re-streams that segment's weights
FORWARD through ``relay_scan`` (the same prefetch ring / G-grouping /
packed transport as every other relay) to recompute the K-1 missing
boundaries from the stored entry — each re-hosted into the stash tier as
it is produced and fetched back one layer at a time by the segment's
recompute-vjp backward relay (the K=1 protocol), so the device boundary
working set stays O(1) in both N and K.  Chen-style sublinear
checkpointing composed into the relay: one extra layer-forward for K-1
of every K layers, bit-identical gradients and updates for every (K, G,
prefetch, pack) point (tests/test_stash.py).  K = 1 emits the historical
single-scan schedule unchanged.  K > 1 used to unroll ~3·ceil(N/K)
relay instances (fwd + recompute + bwd per segment) — with
``ExecutionConfig.segment_scan`` (default on) each phase is instead ONE
outer ``segment_scan`` over the N//K full segments (traced segment
start, static remainder epilogue), so the compiled program is O(1) in
depth; ``segment_scan=False`` re-emits the historical unrolled program
bit-identically.  ``dynamic_depth`` builds on that: the step takes the
live layer count as a traced int32 operand (``n_active``), layers past
it ride idle ``lax.cond`` branches that pass activations through and
re-ship their param/optimizer rows bit-frozen, so ONE compiled program
serves every depth up to the capacity the weights were sized at.
With ``eager_optimizer`` (Alg 4 / L2L-p) the optimizer for layer l runs
inside the same reverse step, overlapping the backward of layer l-1 —
and because the body's dw is produced under pjit, the per-layer gradient
all-reduce is issued layer-by-layer too ("parallel reduce").

Gradient identity: this computes exactly the gradients of
baseline-with-accumulated-gradients (Algorithm 2) — asserted by tests.

Relay transport: every layer scan here (train forward, reverse backward,
Alg-3 trailing update, prefill) is a per-layer body handed to
``repro.core.relay.relay_scan``, which owns the EPS transport exactly
once — weight streaming, the ``prefetch_depth``-deep ring of in-flight
HBM slots, ``pack_params`` flat-buffer slots, and ``layers_per_relay``
G-layer relay groups (one DMA covers G stacked layers; the paper §3.1's
"the executing layer(s)", plural).  Every (G, prefetch_depth,
pack_params) combination computes bit-identical results
(tests/test_relay.py, tests/test_prefetch.py, tests/test_packing.py).

Packed relay (``ExecutionConfig.pack_params``): the stacked group params
(and, in L2L-p, the optimizer slots) arrive as ``packing.Packed`` flat
buffers — one contiguous segment per dtype — so each relay stop moves
ONE large array per direction instead of N per-leaf copies.  The bodies
unpack a zero-copy device-side view for the layer apply, keep every
gradient-side reduction (scale, clip, finiteness) on the original tree
so the math is bit-identical to the unpacked schedule, and run the eager
optimizer directly on the flat segments through ``Optimizer.flat_update``
(the fused Pallas kernel) when available, falling back to unpack ->
per-leaf update -> repack otherwise (tests/test_packing.py).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.eps import EPSPlacements, make_placements
from repro.core.relay import (Stream, flatten_segments, group_slice,
                              relay_scan, segment_bounds, segment_scan)
from repro.core.schedule import ExecutionConfig
from repro.optim import Optimizer, clip_by_norm, tree_global_norm


def _reshape_ub(tree, ub: int):
    def one(a):
        assert a.shape[0] % ub == 0, \
            f"batch {a.shape[0]} not divisible by n_microbatches {ub}"
        return a.reshape(ub, a.shape[0] // ub, *a.shape[1:])
    return jax.tree.map(one, tree)


def _tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def _tree_zeros_f32(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def _seg_slice(tree, s0: int, s1: int):
    """Static layer-range slice of a stacked (N, ...) tree (plain or
    ``packing.Packed`` — both slice on the leading stacked axis)."""
    return jax.tree.map(lambda a: a[s0:s1], tree)


def _concat_segs(trees):
    return jax.tree.map(lambda *ls: jnp.concatenate(ls, axis=0), *trees)


def _make_ship(transport: str) -> Callable:
    """The write-back half of the relay transport: ``ship(place, tree)``
    re-hosts a relay stop's products (boundary stash, shipped grads,
    updated weights / optimizer slots).  Under ``transport="pallas"`` the
    produced buffer first moves through the same double-buffered DMA
    pipeline the stream-in uses (``kernels.relay_copy.writeback_slot`` —
    an identity copy, so the math is untouched), pacing the outbound
    transfer with semaphores exactly like the inbound one."""
    if transport == "pallas":
        from repro.kernels import relay_copy

        def ship(place, tree):
            return place(relay_copy.writeback_slot(tree))
        return ship
    return lambda place, tree: place(tree)


def _make_packed_update(optimizer: Optimizer, exec_cfg: ExecutionConfig,
                        run_opt) -> Callable:
    """Per-layer optimizer step on ``Packed`` flat buffers.

    Fused path: when the optimizer exposes ``flat_update`` (adam/adamw ->
    kernels/fused_adam_flat) and the slots are Adam-shaped, the update
    runs ONCE per dtype segment — one kernel over the whole layer instead
    of a per-leaf chain — reading the (possibly low-precision) weight
    segment and the f32 master moments that stay EPS-resident.  Fallback
    (lamb/sgd/collector, or host_optimizer which must run on the EPS
    host): unpack -> per-leaf ``run_opt`` -> repack.  Both paths are
    bit-identical to the unpacked schedule."""
    def packed_update(dw, opt_l, w_pk, step_i):
        spec = w_pk.spec
        slots = tuple(sorted(opt_l))
        if (optimizer.flat_update is not None and slots == ("m", "v")
                and not exec_cfg.host_optimizer):
            g_pk = dw if packing.is_packed(dw) \
                else packing.pack(dw, spec=spec, stacked=False)
            new_p, new_m, new_v = {}, {}, {}
            for key in sorted(w_pk.segs):
                p2, m2, v2 = optimizer.flat_update(
                    w_pk.segs[key], g_pk.segs[key],
                    opt_l["m"].segs[key], opt_l["v"].segs[key], step_i)
                new_p[key], new_m[key], new_v[key] = p2, m2, v2
            return (packing.Packed(new_p, spec),
                    {"m": packing.Packed(new_m, spec),
                     "v": packing.Packed(new_v, spec)})
        dw_t = packing.unpack(dw) if packing.is_packed(dw) else dw
        nw, no = run_opt(dw_t, packing.unpack_opt(spec, opt_l),
                         packing.unpack(w_pk), step_i)
        return (packing.pack(nw, spec=spec, stacked=False),
                packing.pack_opt(spec, no, stacked=False))
    return packed_update


# ===========================================================================
# Training step factory
# ===========================================================================
def make_train_step(model, optimizer: Optimizer, exec_cfg: ExecutionConfig,
                    placements: Optional[EPSPlacements] = None) -> Callable:
    """Returns step(params, opt_state, batch) -> (params', opt_state',
    metrics).  ``opt_state`` = {"step": i32, "embed":..., "head":...,
    "groups": (stacked per group,)} — build with ``init_opt_state``."""
    if placements is None:
        placements = make_placements(exec_cfg, len(model.groups))
    UB = exec_cfg.n_microbatches
    PF = exec_cfg.prefetch_depth
    PK = exec_cfg.pack_params
    G = exec_cfg.layers_per_relay
    SE = exec_cfg.stash_every
    TR = exec_cfg.transport
    UNROLL = exec_cfg.unroll_layers
    SEG = exec_cfg.segment_scan
    DYN = exec_cfg.dynamic_depth
    if DYN:
        assert len(model.groups) == 1, \
            "dynamic_depth supports single-group models " \
            "(one traced depth bound)"
        assert model.groups[0].n_layers % SE == 0, \
            "dynamic_depth needs stash_every to divide the capacity depth"
    ship = _make_ship(TR)

    def run_opt(grads, opt_l, w, step_i):
        """Apply the optimizer — on the EPS host when host_optimizer (the
        paper's CPU optimizer, eq. (6) O_tc; L2L-p overlaps it)."""
        if exec_cfg.host_optimizer:
            from jax.experimental.compute_on import compute_on
            with compute_on("device_host"):
                return optimizer.update(grads, opt_l, w, step_i)
        return optimizer.update(grads, opt_l, w, step_i)

    packed_update = _make_packed_update(optimizer, exec_cfg, run_opt)

    def step(params, opt_state, batch, n_active=None):
        if DYN:
            assert n_active is not None, \
                "dynamic_depth: the step takes a traced n_layers operand"
            n_act = jnp.asarray(n_active, jnp.int32)
            act_win = (jnp.int32(0), n_act)     # active layer-row window
        else:
            assert n_active is None, \
                "n_layers operand needs ExecutionConfig.dynamic_depth"
            n_act = act_win = None
        static = {"embed": params["embed"], "head": params["head"]}
        batch_ub = _reshape_ub(batch, UB)
        W_total = jnp.maximum(batch["mask"].sum(), 1.0)
        amp = exec_cfg.loss_scale_init > 0
        S_loss = (opt_state["loss_scale"]["scale"] if amp
                  else jnp.float32(1.0))

        # ------------------------------------------------------------
        # FORWARD: layer-major relay through the groups
        # ------------------------------------------------------------
        def prep_one(b):
            x, _ = model.prepare(static, b)
            return x
        x_ub = jax.lax.map(prep_one, batch_ub)            # (UB, Bub, S, d)

        ub_slice = jax.tree.map(lambda a: a[0], batch_ub)
        # per group: boundary inputs — one stacked (N, UB, Bub, S, d)
        # tree with stash_every=1; with K > 1 a PYTHON LIST of the
        # ceil(N/K) segment-entry checkpoint trees (kept unstacked so
        # each stays in the stash placement's memory space — stacking
        # would materialize the checkpoints outside pinned_host on TPU;
        # the backward recomputes the in-between boundaries from them)
        stashes = []
        group_inputs = []     # x_ub at entry of each group (== stash[:,0])
        mems = []             # per group: mem_ub or None
        aux_total = jnp.float32(0.0)

        for gi, group in enumerate(model.groups):
            if gi > 0:
                x_prev = x_ub
                x_ub = jax.lax.map(
                    lambda b_x: model.transition_x(gi, static, b_x[1], b_x[0]),
                    (batch_ub, x_prev))
                mem_ub = (jax.lax.map(
                    lambda b_x: model.transition_mem(gi, static, b_x[1],
                                                     b_x[0]),
                    (batch_ub, x_prev)) if group.has_mem else None)
                group_inputs.append(x_prev)   # saved for transition vjp
            else:
                mem_ub = None
                group_inputs.append(None)
            mems.append(mem_ub)
            ctx = model.train_ctx(ub_slice, group)
            wp = placements.weights[gi]

            def fwd_body(x_c, slots, _x, _g=group, _ctx=ctx, _mem=mem_ub,
                         _stash=True):
                """Microbatch loop of one layer (slot already in HBM)."""
                (w,) = slots
                if PK:
                    w = packing.unpack(w)   # zero-copy views on the buffer
                def ub_body(aux_c, args):
                    if _mem is None:
                        x_i = args
                        y, aux = _g.apply(w, x_i, None, _ctx)
                    else:
                        x_i, m_i = args
                        y, aux = _g.apply(w, x_i, m_i, _ctx)
                    return aux_c + aux.astype(jnp.float32), y
                xs = x_c if _mem is None else (x_c, _mem)
                aux_g, y_ub = jax.lax.scan(ub_body, jnp.float32(0.0), xs)
                return y_ub, ((ship(placements.stash.host, x_c), aux_g)
                              if _stash else aux_g)

            if SE == 1:
                fwd_idle = None
                if DYN:
                    def fwd_idle(x_c, slots, _x):
                        # inactive layer: activations pass through
                        # untouched; the boundary ships anyway (the ys
                        # avals must match the live branch)
                        return x_c, (ship(placements.stash.host, x_c),
                                     jnp.float32(0.0))
                x_ub, (stash_g, aux_per_layer) = relay_scan(
                    fwd_body, x_ub, (Stream(wp, params["groups"][gi]),),
                    group=G, prefetch=PF, unroll=UNROLL, transport=TR,
                    active=act_win, idle_body=fwd_idle)
                stashes.append(stash_g)
                aux_total = aux_total + aux_per_layer.sum() / UB
            else:
                # constant-memory stash: checkpoint ONLY each K-segment's
                # entry boundary; the segment's layers relay through the
                # same executor (ring/grouping/packing intact) without
                # emitting per-layer stash ys.
                def fwd_nostash(x_c, slots, x, _b=fwd_body):
                    return _b(x_c, slots, x, _stash=False)

                if not SEG:
                    # historical unrolled per-segment relays — one
                    # program instance per segment, kept as the
                    # compile-time A/B baseline (segment_scan=False)
                    stash_segs = []
                    for s0, s1 in segment_bounds(group.n_layers, SE):
                        stash_segs.append(ship(placements.stash.host, x_ub))
                        x_ub, aux_per_layer = relay_scan(
                            fwd_nostash, x_ub,
                            (Stream(wp, _seg_slice(params["groups"][gi],
                                                   s0, s1)),),
                            group=G, prefetch=PF, unroll=UNROLL,
                            transport=TR)
                        aux_total = aux_total + aux_per_layer.sum() / UB
                    stashes.append(stash_segs)
                else:
                    # segment-major: ONE outer scan walks the full
                    # K-segments (traced start -> dynamic weight slices);
                    # aux accumulation rides the carry so the float adds
                    # keep the unrolled left-to-right order, and the
                    # entry checkpoints become the outer scan's ys (the
                    # same ship-into-stash-tier protocol K=1 uses).
                    fwd_idle = None
                    if DYN:
                        def fwd_idle(x_c, slots, _x):
                            return x_c, jnp.float32(0.0)

                    w_g = params["groups"][gi]

                    def seg_fwd(carry, s0, size, _x, win, _wp=wp,
                                _w=w_g, _idle=fwd_idle):
                        x_c, aux_c = carry
                        entry = ship(placements.stash.host, x_c)
                        x_c, aux_per_layer = relay_scan(
                            fwd_nostash, x_c,
                            (Stream(_wp, group_slice(_w, s0, size)),),
                            group=G, prefetch=PF, unroll=UNROLL,
                            transport=TR, active=win, idle_body=_idle)
                        return (x_c, aux_c + aux_per_layer.sum() / UB), \
                            entry

                    (x_ub, aux_total), st_scan, st_rem = segment_scan(
                        seg_fwd, (x_ub, aux_total),
                        n_layers=group.n_layers, every=SE,
                        n_active=n_act, unroll=UNROLL)
                    stashes.append((st_scan, st_rem))

        # ------------------------------------------------------------
        # HEAD: loss + dL/dx per microbatch (also d_static from the head)
        # ------------------------------------------------------------
        def head_ub(carry, args):
            d_static_acc, loss_acc = carry
            x_i, b_i = args
            def f(s, xx):
                ls, ws = model.head_loss(s, xx, b_i)
                return ls
            loss_i, vjp = jax.vjp(f, static, x_i)
            ds_i, dx_i = vjp(S_loss / W_total)
            return (_tree_add(d_static_acc, jax.tree.map(
                lambda a: a.astype(jnp.float32), ds_i)),
                loss_acc + loss_i), dx_i

        (d_static, loss_sum), dx_ub = jax.lax.scan(
            head_ub, (_tree_zeros_f32(static), jnp.float32(0.0)),
            (x_ub, batch_ub))
        loss = loss_sum / W_total + aux_total

        # ------------------------------------------------------------
        # BACKWARD: reverse relay; recompute-vjp per layer; eager opt
        # ------------------------------------------------------------
        new_group_params = [None] * len(model.groups)
        new_group_opt = [None] * len(model.groups)
        group_grads = [None] * len(model.groups)  # only if not eager
        gnorm_sq = jnp.float32(0.0)
        nonfinite = jnp.int32(0)
        opt_step = opt_state["step"]

        for gi in reversed(range(len(model.groups))):
            group = model.groups[gi]
            ctx = model.train_ctx(ub_slice, group)
            mem_ub = mems[gi]
            has_mem = mem_ub is not None
            wp, op = placements.weights[gi], placements.opts[gi]

            dmem_ub = (jax.tree.map(
                lambda a: jnp.zeros(a.shape, a.dtype), mem_ub)
                if has_mem else None)

            def bwd_body(core, slots, stash_l, _g=group, _ctx=ctx,
                         _mem=mem_ub, _wp=wp, _op=op, _has_mem=has_mem):
                """Recompute-vjp microbatch loop (+ eager opt) of one
                layer; the slots are already the HBM-resident slices.
                With pack_params the vjp differentiates the UNPACKED view
                and every gradient-side reduction below stays on the tree,
                so the packed schedule's math is bit-identical."""
                w_dev = slots[0]
                opt_l = slots[1] if len(slots) > 1 else None
                dx_c, dmem_c, gn_c, nf_c = core
                w_tree = packing.unpack(w_dev) if PK else w_dev
                stash_dev = placements.stash.dev(stash_l)

                def ub_body(dw_acc, args):
                    if _has_mem:
                        x_in, dx_i, m_i = args
                        def f(ww, xx, mm):
                            return _g.apply(ww, xx, mm, _ctx)
                        _, vjp = jax.vjp(f, w_tree, x_in, m_i)
                        dw_i, dxin_i, dmem_i = vjp(
                            (dx_i, S_loss / UB))
                    else:
                        x_in, dx_i = args
                        def f(ww, xx):
                            return _g.apply(ww, xx, None, _ctx)
                        _, vjp = jax.vjp(f, w_tree, x_in)
                        dw_i, dxin_i = vjp((dx_i, S_loss / UB))
                        dmem_i = None
                    dw_acc = _tree_add(dw_acc, jax.tree.map(
                        lambda a: a.astype(jnp.float32), dw_i))
                    ys = (dxin_i, dmem_i) if _has_mem else dxin_i
                    return dw_acc, ys

                args = (stash_dev, dx_c, _mem) if _has_mem \
                    else (stash_dev, dx_c)
                dw, ys = jax.lax.scan(
                    ub_body, _tree_zeros_f32(w_tree), args)
                if _has_mem:
                    dxin_ub, dmem_ub_l = ys
                    dmem_c = _tree_add(dmem_c, dmem_ub_l)
                else:
                    dxin_ub = ys
                dw = jax.tree.map(lambda g: g / S_loss, dw)
                finite_l = jnp.all(jnp.stack([
                    jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(dw)]))
                if exec_cfg.clip_mode == "per_layer":
                    dw, _ = clip_by_norm(dw, exec_cfg.clip_norm)
                gn_c = gn_c + jnp.where(finite_l,
                                        tree_global_norm(dw) ** 2, 0.0)
                if exec_cfg.eager_optimizer:
                    new_w, new_opt = (packed_update if PK else run_opt)(
                        dw, opt_l, w_dev, opt_step)
                    if amp:
                        # L2L-adapted AMP: a non-finite layer skips ITS
                        # update (eager updates can't await a global check)
                        new_w = jax.tree.map(
                            lambda n, o: jnp.where(finite_l, n, o),
                            new_w, w_dev)
                        new_opt = jax.tree.map(
                            lambda n, o: jnp.where(finite_l, n, o),
                            new_opt, opt_l)
                    out = (ship(_wp.host, new_w), ship(_op.host, new_opt))
                else:
                    # Alg 3: gradients are shipped to the EPS (host) and the
                    # update happens in a trailing layer loop — packed, the
                    # shipment is one flat f32 segment aligned to the
                    # weight layout instead of N leaf copies.
                    out = ship(_wp.host,
                               packing.pack(dw, spec=w_dev.spec,
                                            stacked=False)
                               if PK else dw)
                nf_c = nf_c + jnp.where(finite_l, 0, 1)
                return (dxin_ub, dmem_c, gn_c, nf_c), out

            bwd_idle = None
            if DYN:
                def bwd_idle(core, slots, _stash, _wp=wp, _op=op):
                    """Inactive layer: the carry (dx, dmem, gnorm,
                    nonfinite) passes through untouched; the write-back
                    ys re-ship the incoming rows (eager: the row's
                    params/opt slots stay bit-identical) or a zero
                    gradient (trailing-update mode)."""
                    if exec_cfg.eager_optimizer:
                        return core, (ship(_wp.host, slots[0]),
                                      ship(_op.host, slots[1]))
                    w_tree = packing.unpack(slots[0]) if PK else slots[0]
                    dw0 = _tree_zeros_f32(w_tree)
                    return core, ship(
                        _wp.host,
                        packing.pack(dw0, spec=slots[0].spec,
                                     stacked=False) if PK else dw0)

            core0 = (dx_ub, dmem_ub, gnorm_sq, nonfinite)
            if SE == 1:
                streams = [Stream(wp, params["groups"][gi])]
                if exec_cfg.eager_optimizer:
                    # L2L-p: the optimizer slice rides the same relay
                    # ring; the updated-weight write-back (stacked ys) is
                    # consumed only after the scan — it overlaps the next
                    # backward.
                    streams.append(Stream(op, opt_state["groups"][gi]))
                core0, outs = relay_scan(
                    bwd_body, core0, streams, xs=stashes[gi], reverse=True,
                    group=G, prefetch=PF, unroll=UNROLL, transport=TR,
                    active=act_win, idle_body=bwd_idle)
            else:
                # Constant-memory stash: walk the K-segments in reverse.
                # Each segment first re-streams its weights FORWARD
                # through the relay executor (same ring/grouping/packing)
                # to recompute the K-1 boundaries between its stored
                # entry checkpoint and the next one, then runs the
                # recompute-vjp backward relay over the segment.  Each
                # recomputed boundary is RE-HOSTED into the stash
                # placement as it is produced and fetched back one layer
                # at a time by the backward (exactly the K=1 protocol),
                # so the device never holds more than one boundary of
                # recompute working set regardless of K.
                def rec_body(x_c, slots, _x, _g=group, _ctx=ctx,
                             _mem=mem_ub):
                    """One layer of the boundary-recompute forward: the
                    same microbatch loop as the forward relay (aux
                    discarded); ys = the layer's OUTPUT boundary, placed
                    into the stash tier."""
                    (w,) = slots
                    if PK:
                        w = packing.unpack(w)
                    def ub_body(_, args):
                        if _mem is None:
                            y, _aux = _g.apply(w, args, None, _ctx)
                        else:
                            x_i, m_i = args
                            y, _aux = _g.apply(w, x_i, m_i, _ctx)
                        return None, y
                    xs_l = x_c if _mem is None else (x_c, _mem)
                    _, y_ub = jax.lax.scan(ub_body, None, xs_l)
                    return y_ub, ship(placements.stash.host, y_ub)

                if not SEG:
                    # historical unrolled per-segment relays
                    # (segment_scan=False compile-time A/B baseline)
                    bounds = segment_bounds(group.n_layers, SE)
                    outs_segs = [None] * len(bounds)
                    for si in reversed(range(len(bounds))):
                        s0, s1 = bounds[si]
                        entry = stashes[gi][si]          # host-placed
                        if s1 - s0 > 1:
                            _, rec_bounds = relay_scan(
                                rec_body, placements.stash.dev(entry),
                                (Stream(wp,
                                        _seg_slice(params["groups"][gi],
                                                   s0, s1 - 1)),),
                                group=G, prefetch=PF, unroll=UNROLL,
                                transport=TR)
                            # entry + outputs of layers s0..s1-2
                            # == boundaries of layers s0..s1-1
                            seg_stash = jax.tree.map(
                                lambda e, bs: jnp.concatenate(
                                    [e[None], bs], axis=0),
                                entry, rec_bounds)
                        else:
                            seg_stash = jax.tree.map(
                                lambda a: a[None], entry)
                        seg_streams = [Stream(
                            wp, _seg_slice(params["groups"][gi], s0, s1))]
                        if exec_cfg.eager_optimizer:
                            seg_streams.append(Stream(op, _seg_slice(
                                opt_state["groups"][gi], s0, s1)))
                        core0, outs_segs[si] = relay_scan(
                            bwd_body, core0, seg_streams, xs=seg_stash,
                            reverse=True, group=G, prefetch=PF,
                            unroll=UNROLL, transport=TR)
                    # per-segment write-backs concatenate to the (N, ...)
                    # group tree; re-state the EPS placement on the
                    # result so it lands host-resident like the K=1
                    # scan-stacked ys
                    outs = _concat_segs(outs_segs)
                else:
                    # segment-major: the reverse walk over segments is
                    # ONE outer scan (the entry checkpoints ride its xs);
                    # each iteration re-streams its segment's weights
                    # forward to recompute the missing boundaries, then
                    # runs the recompute-vjp backward — exactly the
                    # unrolled schedule, with a traced segment start
                    # feeding dynamic weight/opt slices.
                    rec_idle = None
                    if DYN:
                        def rec_idle(x_c, slots, _x):
                            return x_c, ship(placements.stash.host, x_c)

                    w_g = params["groups"][gi]
                    o_g = (opt_state["groups"][gi]
                           if exec_cfg.eager_optimizer else None)

                    def seg_bwd(core, s0, size, entry, win, _wp=wp,
                                _op=op, _w=w_g, _o=o_g, _ri=rec_idle):
                        if size > 1:
                            # active rows [0, hi): the recompute needs
                            # boundaries 1..hi-1 = outputs of rows
                            # 0..hi-2, so its window is (0, hi-1)
                            rec_win = (None if win is None else
                                       (win[0],
                                        jnp.maximum(win[1] - 1, 0)))
                            _, rec_bounds = relay_scan(
                                rec_body, placements.stash.dev(entry),
                                (Stream(_wp,
                                        group_slice(_w, s0, size - 1)),),
                                group=G, prefetch=PF, unroll=UNROLL,
                                transport=TR, active=rec_win,
                                idle_body=_ri)
                            # entry + outputs of rows 0..size-2
                            # == boundaries of rows 0..size-1
                            seg_stash = jax.tree.map(
                                lambda e, bs: jnp.concatenate(
                                    [e[None], bs], axis=0),
                                entry, rec_bounds)
                        else:
                            seg_stash = jax.tree.map(
                                lambda a: a[None], entry)
                        seg_streams = [Stream(
                            _wp, group_slice(_w, s0, size))]
                        if exec_cfg.eager_optimizer:
                            seg_streams.append(Stream(
                                _op, group_slice(_o, s0, size)))
                        return relay_scan(
                            bwd_body, core, seg_streams, xs=seg_stash,
                            reverse=True, group=G, prefetch=PF,
                            unroll=UNROLL, transport=TR, active=win,
                            idle_body=bwd_idle)

                    st_scan, st_rem = stashes[gi]
                    core0, outs_scan, outs_rem = segment_scan(
                        seg_bwd, core0, n_layers=group.n_layers,
                        every=SE, xs=st_scan, xs_rem=st_rem,
                        reverse=True, n_active=n_act, unroll=UNROLL)
                    outs = flatten_segments(outs_scan, outs_rem)
                # re-state the EPS placement on the stitched result so it
                # lands host-resident like the K=1 scan-stacked ys
                outs = ((wp.host(outs[0]), op.host(outs[1]))
                        if exec_cfg.eager_optimizer else wp.host(outs))
            dx_ub, dmem_ub, gnorm_sq, nonfinite = core0
            if exec_cfg.eager_optimizer:
                new_group_params[gi], new_group_opt[gi] = outs
            else:
                group_grads[gi] = outs

            # ---- transition vjp back to the previous group -----------
            if gi > 0:
                x_prev_ub = group_inputs[gi]

                def trans_ub(d_static_acc, args):
                    b_i, xp_i, dxin_i, dmem_i = args
                    def fx(s, xp):
                        return model.transition_x(gi, s, xp, b_i)
                    _, vjp_x = jax.vjp(fx, static, xp_i)
                    ds_x, dxp_x = vjp_x(dxin_i)
                    if dmem_i is not None:
                        def fm(s, xp):
                            return model.transition_mem(gi, s, xp, b_i)
                        _, vjp_m = jax.vjp(fm, static, xp_i)
                        ds_m, dxp_m = vjp_m(dmem_i)
                        ds_x = _tree_add(ds_x, ds_m)
                        dxp_x = dxp_x + dxp_m
                    return _tree_add(d_static_acc, jax.tree.map(
                        lambda a: a.astype(jnp.float32), ds_x)), dxp_x

                if has_mem:
                    d_static, dx_ub = jax.lax.scan(
                        trans_ub, d_static,
                        (batch_ub, x_prev_ub, dx_ub, dmem_ub))
                else:
                    def trans_ub_nomem(d_static_acc, args):
                        b_i, xp_i, dxin_i = args
                        def fx(s, xp):
                            return model.transition_x(gi, s, xp, b_i)
                        _, vjp_x = jax.vjp(fx, static, xp_i)
                        ds_x, dxp_x = vjp_x(dxin_i)
                        return _tree_add(d_static_acc, jax.tree.map(
                            lambda a: a.astype(jnp.float32), ds_x)), dxp_x
                    d_static, dx_ub = jax.lax.scan(
                        trans_ub_nomem, d_static,
                        (batch_ub, x_prev_ub, dx_ub))

        # ---- prepare (embedding) vjp ---------------------------------
        def prep_ub(d_static_acc, args):
            b_i, dx_i = args
            def f(s):
                x, _ = model.prepare(s, b_i)
                return x
            _, vjp = jax.vjp(f, static)
            (ds_i,) = vjp(dx_i)
            return _tree_add(d_static_acc, jax.tree.map(
                lambda a: a.astype(jnp.float32), ds_i)), None

        d_static, _ = jax.lax.scan(prep_ub, d_static, (batch_ub, dx_ub))
        gnorm_sq = gnorm_sq + tree_global_norm(d_static) ** 2

        # ------------------------------------------------------------
        # UPDATES (trailing update: static params; layer params if not eager)
        # ------------------------------------------------------------
        d_static = jax.tree.map(lambda g: g / S_loss, d_static)
        finite_s = jnp.all(jnp.stack([
            jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(d_static)]))
        nonfinite = nonfinite + jnp.where(finite_s, 0, 1)
        if exec_cfg.clip_mode == "per_layer":
            d_static, _ = clip_by_norm(d_static, exec_cfg.clip_norm)
        new_static, new_static_opt = optimizer.update(
            d_static, {"embed": opt_state["embed"], "head": opt_state["head"]},
            static, opt_step)
        if amp:
            new_static = jax.tree.map(
                lambda n, o: jnp.where(finite_s, n, o), new_static, static)
            new_static_opt = jax.tree.map(
                lambda n, o: jnp.where(finite_s, n, o), new_static_opt,
                {"embed": opt_state["embed"], "head": opt_state["head"]})

        if not exec_cfg.eager_optimizer:
            # Alg 3: separate trailing loop over layers (still layer-major).
            # Triple relay: weight, gradient (shipped to the EPS by the
            # backward, same placement as weights) and optimizer slots of
            # the NEXT stop stream in while this one updates.
            for gi, group in enumerate(model.groups):
                wp, op = placements.weights[gi], placements.opts[gi]
                streams = (Stream(wp, params["groups"][gi]),
                           Stream(wp, group_grads[gi]),
                           Stream(op, opt_state["groups"][gi]))

                def upd_body(_, slots, _x, _wp=wp, _op=op):
                    w, g, o = slots
                    nw, no = (packed_update if PK else run_opt)(
                        g, o, w, opt_step)
                    return None, (ship(_wp.host, nw), ship(_op.host, no))

                upd_idle = None
                if DYN:
                    def upd_idle(_, slots, _x, _wp=wp, _op=op):
                        # inactive row: no update — re-ship the incoming
                        # rows so adam's moment decay never touches them
                        w, g, o = slots
                        return None, (ship(_wp.host, w),
                                      ship(_op.host, o))

                _, (nw_g, no_g) = relay_scan(
                    upd_body, None, streams,
                    group=G, prefetch=PF, unroll=UNROLL, transport=TR,
                    active=act_win, idle_body=upd_idle)
                new_group_params[gi] = nw_g
                new_group_opt[gi] = no_g

        new_params = {"embed": new_static["embed"],
                      "head": new_static["head"],
                      "groups": tuple(new_group_params)}
        new_opt = {"step": opt_step + 1,
                   "embed": new_static_opt["embed"],
                   "head": new_static_opt["head"],
                   "groups": tuple(new_group_opt)}
        metrics = {"loss": loss, "aux": aux_total,
                   "grad_norm": jnp.sqrt(gnorm_sq),
                   "weight_sum": W_total}
        if exec_cfg.skip_nonfinite:
            # anomaly sentinel: ANY non-finite layer/static gradient
            # rejects the whole step — params, opt slots and the step
            # counter come back bit-identical to the pre-step state
            # (``jnp.where`` passes the prior operand through untouched),
            # whatever the (G, prefetch, pack, K) relay produced above.
            # The AMP loss scale (attached below) still adapts on a
            # rejected step, so overflow recovery converges.
            bad = nonfinite > 0

            def keep(new, old):
                return jax.tree.map(
                    lambda a, o: jnp.where(bad, o, a), new, old)

            new_params = keep(new_params, params)
            new_opt = {k: keep(new_opt[k], opt_state[k])
                       for k in ("step", "embed", "head", "groups")}
            metrics["skipped_steps"] = jnp.where(bad, 1, 0).astype(jnp.int32)
            metrics["nonfinite_layers"] = nonfinite
        if amp:
            ls = opt_state["loss_scale"]
            any_bad = nonfinite > 0
            good = jnp.where(any_bad, 0, ls["good_steps"] + 1)
            scale = jnp.where(any_bad,
                              jnp.maximum(ls["scale"] * 0.5, 1.0),
                              ls["scale"])
            grow = good >= exec_cfg.loss_scale_growth
            scale = jnp.where(grow, scale * 2.0, scale)
            good = jnp.where(grow, 0, good)
            new_opt["loss_scale"] = {"scale": scale, "good_steps": good}
            metrics["loss_scale"] = scale
            metrics["nonfinite_layers"] = nonfinite
        return new_params, new_opt, metrics

    return step


# ===========================================================================
# Prefill (inference forward): layer-major relay, no stash, no backward
# ===========================================================================
def make_prefill_fn(model, exec_cfg: ExecutionConfig,
                    placements: Optional[EPSPlacements] = None) -> Callable:
    """Returns prefill(params, batch) -> last-token logits (B, vocab).
    Exercises the full prefill compute with the L2L weight relay."""
    if placements is None:
        placements = make_placements(exec_cfg, len(model.groups))
    UB = exec_cfg.n_microbatches
    PF = exec_cfg.prefetch_depth
    PK = exec_cfg.pack_params
    G = exec_cfg.layers_per_relay
    TR = exec_cfg.transport
    DYN = exec_cfg.dynamic_depth
    if DYN:
        assert len(model.groups) == 1, \
            "dynamic_depth supports single-group models"

    def prefill(params, batch, n_active=None):
        if DYN:
            assert n_active is not None, \
                "dynamic_depth: prefill takes a traced n_layers operand"
            act_win = (jnp.int32(0), jnp.asarray(n_active, jnp.int32))
        else:
            assert n_active is None, \
                "n_layers operand needs ExecutionConfig.dynamic_depth"
            act_win = None
        static = {"embed": params["embed"], "head": params["head"]}
        batch_ub = _reshape_ub(batch, UB)
        ub_slice = jax.tree.map(lambda a: a[0], batch_ub)

        def prep_one(b):
            x, _ = model.prepare(static, b)
            return x
        x_ub = jax.lax.map(prep_one, batch_ub)

        for gi, group in enumerate(model.groups):
            if gi > 0:
                x_prev = x_ub
                x_ub = jax.lax.map(
                    lambda b_x: model.transition_x(gi, static, b_x[1], b_x[0]),
                    (batch_ub, x_prev))
                mem_ub = (jax.lax.map(
                    lambda b_x: model.transition_mem(gi, static, b_x[1],
                                                     b_x[0]),
                    (batch_ub, x_prev)) if group.has_mem else None)
            else:
                mem_ub = None
            ctx = model.train_ctx(ub_slice, group)
            wp = placements.weights[gi]

            def fwd_body(x_c, slots, _x, _g=group, _ctx=ctx, _mem=mem_ub):
                (w,) = slots
                if PK:
                    w = packing.unpack(w)
                def ub_body(_, args):
                    if _mem is None:
                        y, _aux = _g.apply(w, args, None, _ctx)
                    else:
                        x_i, m_i = args
                        y, _aux = _g.apply(w, x_i, m_i, _ctx)
                    return None, y
                xs = x_c if _mem is None else (x_c, _mem)
                _, y_ub = jax.lax.scan(ub_body, None, xs)
                return y_ub, None

            fwd_idle = None
            if DYN:
                def fwd_idle(x_c, slots, _x):
                    return x_c, None

            x_ub, _ = relay_scan(
                fwd_body, x_ub, (Stream(wp, params["groups"][gi]),),
                group=G, prefetch=PF, unroll=exec_cfg.unroll_layers,
                transport=TR, active=act_win, idle_body=fwd_idle)

        # last-position logits per microbatch
        def head_one(x_i):
            return model.decode_logits(static, x_i[:, -1:, :])[:, 0]
        logits_ub = jax.lax.map(head_one, x_ub)
        return logits_ub.reshape(-1, logits_ub.shape[-1])

    return prefill


# ===========================================================================
# Loss+grads only (no optimizer) — for equivalence tests & benchmarks
# ===========================================================================
def make_grads_fn(model, exec_cfg: ExecutionConfig,
                  placements: Optional[EPSPlacements] = None) -> Callable:
    """Returns grads(params, batch) -> (loss, grads) computed with the L2L
    schedule (layer-major, recompute).  Used to assert gradient identity
    with Algorithm 2 and by the Alg-3 benchmarks."""
    # deliberate WHITELIST of the schedule/layout knobs (not a
    # dataclasses.replace): the grad-collector path must not inherit
    # update-time behavior — amp loss scaling (its loss_scale opt state
    # is never initialized here), host_optimizer, clipping
    cfg_noeager = ExecutionConfig(
        n_microbatches=exec_cfg.n_microbatches,
        offload_stash=exec_cfg.offload_stash,
        weight_stream=exec_cfg.weight_stream,
        stash_every=exec_cfg.stash_every,
        segment_scan=exec_cfg.segment_scan,
        dynamic_depth=exec_cfg.dynamic_depth,
        prefetch_depth=exec_cfg.prefetch_depth,
        pack_params=exec_cfg.pack_params,
        layers_per_relay=exec_cfg.layers_per_relay,
        unroll_layers=exec_cfg.unroll_layers,
        transport=exec_cfg.transport,
        eager_optimizer=False, clip_mode="none")
    return _make_loss_and_grads(model, cfg_noeager, placements)


def _make_loss_and_grads(model, exec_cfg, placements=None):
    """L2L forward+backward that RETURNS grads (Alg 3 without the update)."""
    if placements is None:
        placements = make_placements(exec_cfg, len(model.groups))

    base_step = make_train_step(
        model, _grad_collector(), exec_cfg, placements)

    def fn(params, batch, n_active=None):
        opt = init_opt_state(_grad_collector(), params)
        new_params, new_opt, metrics = base_step(params, opt, batch,
                                                 n_active)
        # _grad_collector stores grads in the "m" slot of the opt state
        # (packed groups hold it as one weight-aligned flat f32 segment —
        # unpack so callers always see the plain grad pytree)
        is_slot = lambda x: isinstance(x, dict) and set(x.keys()) == {"m"}
        unwrap = lambda t: jax.tree.map(lambda s: s["m"], t, is_leaf=is_slot)
        grads = {
            "embed": unwrap(new_opt["embed"]),
            "head": unwrap(new_opt["head"]),
            "groups": tuple(
                packing.unpack(g) if packing.is_packed(g) else g
                for g in (unwrap(g) for g in new_opt["groups"])),
        }
        return metrics["loss"], grads

    return fn


def _grad_collector() -> Optimizer:
    """An 'optimizer' that stores the gradient into its state and leaves
    params untouched — lets tests extract L2L grads through the normal
    step machinery."""
    def init(params):
        return jax.tree.map(
            lambda p: {"m": jnp.zeros(p.shape, jnp.float32)}, params)

    def update(grads, state, params, step):
        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = [{"m": g.astype(jnp.float32)} for g in flat_g]
        return params, jax.tree.unflatten(treedef, flat_s)

    return Optimizer("collect", init, update)


# ===========================================================================
# Optimizer state init
# ===========================================================================
def init_opt_state(optimizer: Optimizer, params,
                   exec_cfg: Optional[ExecutionConfig] = None) -> dict:
    def group_opt(g):
        # packed group: slot-major flat buffers aligned to the weight spec
        if packing.is_packed(g):
            return packing.pack_opt(g.spec, optimizer.init(packing.unpack(g)))
        return optimizer.init(g)

    state = {
        "step": jnp.zeros((), jnp.int32),
        "embed": optimizer.init(params["embed"]),
        "head": optimizer.init(params["head"]),
        "groups": tuple(group_opt(g) for g in params["groups"]),
    }
    if exec_cfg is not None and exec_cfg.loss_scale_init > 0:
        state["loss_scale"] = {
            "scale": jnp.float32(exec_cfg.loss_scale_init),
            "good_steps": jnp.zeros((), jnp.int32)}
    return state
