"""Packed flat-buffer parameter relay (ExecutionConfig.pack_params).

The EPS bottleneck is not bandwidth alone — it is the *shape* of the
traffic.  An unpacked layer crosses the host<->HBM boundary as a pytree of
dozens of small per-leaf copies, each paying DMA issue latency; profiling
(BENCH_relay.json) shows those small transfers stay latency-bound, which
is why the double-buffered relay (PR 2) only pays off with
``weight_stream=off``.  This module coalesces each layer into ONE
contiguous flat buffer per dtype, so every relay — forward, reverse
backward, trailing update, prefill, decode — issues one large DMA per
layer per direction instead of N leaf copies.

Representation
--------------
``Packed`` is a registered pytree node holding dtype-segregated segments::

    Packed(segs={"float32": (seg_f32,), "bfloat16": (seg_bf16,)},
           spec=PackSpec(...))

A *stacked* group packs to ``(N_layers, seg)`` arrays; a *layer slice*
(what the relay moves) to ``(seg,)``.  The ``PackSpec`` — static metadata
carried in the pytree aux data, so it survives scans, jit and eval_shape —
records, per original leaf, its segment key (the leaf's dtype), element
offset, size and shape.  Unpacking is a static slice + reshape per leaf:
XLA resolves these to zero-copy views of the relayed buffer, so the layer
apply reads straight out of the DMA destination.

Optimizer state packs *slot-major* and **aligned with the weight spec**:
``{"m": Packed, "v": Packed}`` where each slot buffer uses the SAME
segment keys and offsets as the weights (slot arrays are f32 but grouped
by their parent parameter's dtype).  Element i of the "m"/"v" segment
therefore corresponds to element i of the weight segment — exactly the
layout ``kernels.fused_adam_flat`` consumes: fp32 master moments stay
EPS-resident while the (possibly bf16/fp16) weight segment streams to the
device, the paper's EPS mixed-precision split.

Bit-identity: packing is concatenation of reshaped leaves and unpacking is
the inverse slice — byte-for-byte lossless, asserted across every arch by
tests/test_packing.py.

The stacked ``(N, W)`` row-major segments are ALSO the storage tier's
on-disk format: ``core.tierstore.SegmentStore`` persists exactly these
buffers (one file per dtype segment, one crc32 per layer row), so a
G-layer relay window of a demoted group is one contiguous pread and the
disk tier round-trips bytes with no re-encode.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class LeafSlot(NamedTuple):
    """Where one original leaf lives inside its dtype segment."""
    key: str                      # segment key == str(leaf.dtype)
    offset: int                   # element offset within the segment
    size: int                     # element count
    shape: Tuple[int, ...]        # ONE layer's shape (no stacked axis)


class PackSpec(NamedTuple):
    """Static layout of a packed tree (hashable: lives in pytree aux)."""
    treedef: Any                  # treedef of the original (unpacked) tree
    leaves: Tuple[LeafSlot, ...]  # one per original leaf, flatten order
    seg_sizes: Tuple[Tuple[str, int], ...]   # (key, total elements)

    @property
    def keys(self):
        return tuple(k for k, _ in self.seg_sizes)


@jax.tree_util.register_pytree_with_keys_class
class Packed:
    """Pytree node: dict of dtype-keyed flat segments + its PackSpec."""
    __slots__ = ("segs", "spec")

    def __init__(self, segs: dict, spec: PackSpec):
        self.segs = dict(segs)
        self.spec = spec

    def tree_flatten_with_keys(self):
        keys = sorted(self.segs)
        return ([(jax.tree_util.DictKey(k), self.segs[k]) for k in keys],
                (tuple(keys), self.spec))

    @classmethod
    def tree_unflatten(cls, aux, children):
        keys, spec = aux
        return cls(dict(zip(keys, children)), spec)

    def __repr__(self):
        segs = {k: getattr(v, "shape", v) for k, v in self.segs.items()}
        return f"Packed({segs})"


def is_packed(x) -> bool:
    return isinstance(x, Packed)


def _leaf_layer_shape(leaf, stacked: bool):
    return tuple(leaf.shape[1:] if stacked else leaf.shape)


def build_spec(tree, stacked: bool = True) -> PackSpec:
    """Derive the static layout from a (stacked) tree of arrays or
    ShapeDtypeStructs.  Segment assignment and offsets follow pytree
    flatten order, segregated by leaf dtype."""
    leaves, treedef = jax.tree.flatten(tree)
    offsets: dict = {}
    slots = []
    for leaf in leaves:
        key = str(jnp.dtype(leaf.dtype))
        shape = _leaf_layer_shape(leaf, stacked)
        size = 1
        for d in shape:
            size *= int(d)
        off = offsets.get(key, 0)
        slots.append(LeafSlot(key, off, size, shape))
        offsets[key] = off + size
    seg_sizes = tuple(sorted(offsets.items()))
    return PackSpec(treedef, tuple(slots), seg_sizes)


def _assert_layout(spec: PackSpec, leaves, stacked: bool):
    assert len(leaves) == len(spec.leaves), \
        f"tree has {len(leaves)} leaves, spec describes {len(spec.leaves)}"
    for leaf, slot in zip(leaves, spec.leaves):
        got = _leaf_layer_shape(leaf, stacked)
        assert tuple(got) == tuple(slot.shape), \
            f"leaf shape {got} != spec {slot.shape}"


def pack(tree, spec: PackSpec = None, stacked: bool = True) -> Packed:
    """Coalesce a pytree into per-dtype flat segments.

    With an explicit ``spec`` the SEGMENT ASSIGNMENT of the spec is used
    regardless of the actual leaf dtypes — this is how f32 gradient/moment
    trees pack into weight-aligned segments (the slot-major layout the
    fused optimizer needs).  Without one, the spec is derived from the
    tree itself."""
    if spec is None:
        spec = build_spec(tree, stacked=stacked)
    leaves = spec.treedef.flatten_up_to(tree)
    _assert_layout(spec, leaves, stacked)
    by_key: dict = {k: [] for k in spec.keys}
    for leaf, slot in zip(leaves, spec.leaves):
        flat = leaf.reshape(leaf.shape[0], -1) if stacked \
            else leaf.reshape(-1)
        by_key[slot.key].append(flat)
    segs = {}
    for key, parts in by_key.items():
        if not parts:
            continue
        dts = {str(p.dtype) for p in parts}
        assert len(dts) == 1, \
            f"segment {key!r} mixes dtypes {sorted(dts)} — cannot coalesce"
        segs[key] = jnp.concatenate(parts, axis=-1)
    return Packed(segs, spec)


def unpack(packed: Packed):
    """Inverse of ``pack``: static slice + reshape per leaf (zero-copy
    views on the relayed buffer once XLA folds them)."""
    spec = packed.spec
    out = []
    for slot in spec.leaves:
        seg = packed.segs[slot.key]
        stacked = seg.ndim == 2
        if stacked:
            piece = jax.lax.slice_in_dim(seg, slot.offset,
                                         slot.offset + slot.size, axis=1)
            out.append(piece.reshape((seg.shape[0],) + slot.shape))
        else:
            piece = jax.lax.slice_in_dim(seg, slot.offset,
                                         slot.offset + slot.size, axis=0)
            out.append(piece.reshape(slot.shape))
    return jax.tree.unflatten(spec.treedef, out)


# ---------------------------------------------------------------------------
# Optimizer-state packing (slot-major, weight-aligned)
# ---------------------------------------------------------------------------
def opt_slot_names(opt_tree, spec: PackSpec) -> Tuple[str, ...]:
    """Slot keys of a per-leaf optimizer state ({leaf: {"m":..,"v":..}}).
    Asserted uniform across leaves; () for stateless optimizers (sgd)."""
    dicts = spec.treedef.flatten_up_to(opt_tree)
    if not dicts:
        return ()
    first = tuple(sorted(dicts[0]))
    for d in dicts:
        assert isinstance(d, dict) and tuple(sorted(d)) == first, \
            f"non-uniform optimizer slots: {sorted(d)} vs {list(first)}"
    return first


def pack_opt(spec: PackSpec, opt_tree, stacked: bool = True) -> dict:
    """{slot: Packed} with segments ALIGNED to the weight spec (same keys,
    same offsets), so slot element i pairs with weight element i."""
    dicts = spec.treedef.flatten_up_to(opt_tree)
    slots = opt_slot_names(opt_tree, spec)
    out = {}
    for s in slots:
        tree = jax.tree.unflatten(spec.treedef, [d[s] for d in dicts])
        out[s] = pack(tree, spec=spec, stacked=stacked)
    return out


def unpack_opt(spec: PackSpec, packed_slots: dict):
    """Inverse of ``pack_opt``: rebuild {leaf: {slot: arr}}."""
    slots = tuple(sorted(packed_slots))
    unpacked = {s: spec.treedef.flatten_up_to(unpack(packed_slots[s]))
                for s in slots}
    n = len(spec.leaves)
    per_leaf = [{s: unpacked[s][i] for s in slots} for i in range(n)]
    return jax.tree.unflatten(spec.treedef, per_leaf)


def opt_is_packed(group_opt) -> bool:
    return (isinstance(group_opt, dict)
            and all(is_packed(v) for v in group_opt.values()))


# ---------------------------------------------------------------------------
# Whole-params / legacy-opt converters (the checkpoint + facade boundary)
# ---------------------------------------------------------------------------
def pack_params(params: dict) -> dict:
    """Pack the stacked layer groups of a legacy params dict; ``embed`` /
    ``head`` stay plain pytrees (they are never relayed)."""
    return {**params,
            "groups": tuple(g if is_packed(g) else pack(g)
                            for g in params["groups"])}


def unpack_params(params: dict) -> dict:
    return {**params,
            "groups": tuple(unpack(g) if is_packed(g) else g
                            for g in params["groups"])}


def pack_opt_state(opt: dict, params_packed: dict) -> dict:
    """Pack the ``groups`` of a legacy opt-state dict against the packed
    params' specs (slot-major, weight-aligned)."""
    groups = []
    for g_opt, g_p in zip(opt["groups"], params_packed["groups"]):
        groups.append(pack_opt(g_p.spec, g_opt)
                      if is_packed(g_p) and not opt_is_packed(g_opt)
                      else g_opt)
    return {**opt, "groups": tuple(groups)}


def unpack_opt_state(opt: dict, params_packed: dict) -> dict:
    groups = []
    for g_opt, g_p in zip(opt["groups"], params_packed["groups"]):
        groups.append(unpack_opt(g_p.spec, g_opt)
                      if is_packed(g_p) and opt_is_packed(g_opt)
                      else g_opt)
    return {**opt, "groups": tuple(groups)}
