"""Storage-tier EPS: a verified, self-healing NVMe/mmap segment store.

The paper's EPS keeps the stacked layer state in host DRAM; MegaTrain
(PAPERS.md) pushes the same relay below that, to disk.  This module is
that third tier: ``SegmentStore`` persists each layer group's packed flat
segments (``core.packing``'s (N, W) per-dtype row-major buffers — one
contiguous file per group per segment, so a G-layer relay window is ONE
contiguous pread) and ``TierChain`` demotes the cold tail of the stacked
state to it under a host-byte budget, re-materializing demoted rows
around every jitted call.

Durability + integrity are checkpoint-grade, reusing ``checkpoint.io``'s
primitives:

* writes are staged in a ``.tmp-*`` sibling, every file fsynced, the
  directory atomically renamed into place and the parent fsynced — a
  crash leaves the previous segment intact or the new one complete,
  never a torn file under the real name;
* the per-segment manifest carries a whole-file crc32 (verified at
  OPEN), a crc32 PER LAYER ROW (verified on every read — rot under the
  page cache surfaces at the read that returns it, not as NaNs ten
  layers later), and a manifest self-checksum;
* transient read errors (EIO and friends) are retried with exponential
  backoff up to ``retries`` attempts, then surfaced as a hard
  ``TierReadError``;
* a checksum failure quarantines the segment (moved aside, never
  silently overwritten) and rebuilds it from the newest good checkpoint
  through the installed ``rebuilder`` — counted in
  ``metrics["rebuilt_segments"]`` — so one rotten block does not abort
  the step loop.

Graceful degradation: when the resident state would exceed
``host_budget`` the chain demotes whole layer rows (coldest last-group
rows first) instead of OOMing, and the read-side prefetch ring issues
disk reads ``prefetch_depth`` relay-stop-sized chunks ahead; a watchdog
shrinks the ring's effective depth when the budget slack cannot hold the
in-flight chunks (``metrics["prefetch_shrinks"]``) rather than blowing
the budget it exists to protect.

Read-side fast paths: segment spans are served zero-copy from an mmap
of the segment file where the platform supports it (crc verification
and ``np.frombuffer`` run directly over the mapped view; ``pread`` is
the fallback — ``metrics["mmap_reads"]``/``metrics["pread_reads"]``
count the split), and with a read-ahead ring (``prefetch_depth >= 1``)
``stage_out`` kicks off the NEXT relay window's cold-segment fetches in
the background so the disk round-trip overlaps everything between steps
instead of serializing before the jit
(``metrics["async_stage_hits"]``/``metrics["async_stage_misses"]``).

Bit-identity: the store round-trips raw array bytes (no re-encode), and
packing/unpacking are lossless, so a tier-chain run is byte-identical to
the host-only relay for every (G, prefetch, pack, K) point —
tests/test_tierstore.py proves it the same way every prior knob was.
"""
from __future__ import annotations

import errno
import json
import os
import re
import shutil
import time
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Tuple

try:
    import mmap as _mmap
except ImportError:                                  # pragma: no cover
    _mmap = None

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import _fsync_dir, _fsync_file, _manifest_crc
from repro.core import packing
from repro.core.relay import stop_bounds

MANIFEST = "manifest.json"
_TMP = ".tmp-"
QUARANTINE = "quarantine"

# errnos treated as transient (retried with backoff); anything else —
# and a retry budget exhausted on these — is a hard TierReadError
_TRANSIENT = {errno.EIO, errno.EAGAIN, errno.EINTR, errno.EBUSY}


class TierError(RuntimeError):
    """Base class for storage-tier failures."""


class TierReadError(TierError):
    """A segment read failed past the retry budget."""


class TierIntegrityError(TierError):
    """A segment failed verification and could not be rebuilt."""


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _safe(name: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "_", name)


def fresh_metrics() -> Dict[str, int]:
    return {"reads": 0, "read_bytes": 0, "writes": 0, "write_bytes": 0,
            "mmap_reads": 0, "pread_reads": 0,
            "retries": 0, "rebuilt_segments": 0, "quarantined": 0,
            "prefetch_shrinks": 0, "effective_depth": 0,
            "async_stage_hits": 0, "async_stage_misses": 0}


# ===========================================================================
# SegmentStore — one directory per key, one .bin per flat segment
# ===========================================================================
class SegmentStore:
    """Packed flat segments on disk, verified at open and on every read.

    Layout: ``<root>/<key>/seg_<segname>.bin`` (raw row-major (N, W)
    bytes) + ``<root>/<key>/manifest.json``.  ``key`` names one layer
    group's role (e.g. ``g0_w``, ``g0_opt``); segment names are the
    packed dtype keys (weights) or ``<slot>:<dtype>`` (optimizer).

    ``rebuilder`` (installed by ``TierChain.attach_checkpoints``) is
    called with the key when a segment fails verification after
    quarantine; it must re-``put`` the segment from an authoritative
    source (the newest good checkpoint) or raise.
    """

    def __init__(self, root: str, *, retries: int = 3,
                 backoff_s: float = 0.01,
                 use_mmap: Optional[bool] = None):
        self.root = root
        self.retries = max(0, int(retries))
        self.backoff_s = float(backoff_s)
        self.rebuilder: Optional[Callable[[str], None]] = None
        # test seam: called as fault_hook(path, offset, length) before
        # every physical segment read (repro.testing.faults installs
        # seeded EIO / latency injectors here); fires on the mmap path
        # too, so the chaos injectors see every read regardless of path
        self.fault_hook: Optional[Callable[[str, int, int], None]] = None
        # zero-copy reads: crc + frombuffer run directly over the mapped
        # view (the page cache IS the buffer); None = mmap if available
        self.use_mmap = (_mmap is not None) if use_mmap is None \
            else bool(use_mmap)
        self._mmaps: Dict[str, Any] = {}        # path -> live mmap
        self.metrics = fresh_metrics()
        self._manifests: Dict[str, dict] = {}   # verified-at-open cache
        os.makedirs(root, exist_ok=True)

    # -- paths -------------------------------------------------------------
    def key_dir(self, key: str) -> str:
        return os.path.join(self.root, _safe(key))

    def seg_path(self, key: str, seg: str) -> str:
        return os.path.join(self.key_dir(key), f"seg_{_safe(seg)}.bin")

    # -- write path --------------------------------------------------------
    def put(self, key: str, segs: Dict[str, np.ndarray], step: int) -> None:
        """Atomically (re)write one key's segments: staged + fsynced +
        renamed, with per-row and whole-file crc32s in the manifest."""
        final = self.key_dir(key)
        tmp = os.path.join(self.root, _TMP + _safe(key) + f".{os.getpid()}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest: dict = {"version": 1, "key": key, "step": int(step),
                          "segs": {}}
        try:
            for name, arr in segs.items():
                arr = np.ascontiguousarray(np.asarray(jax.device_get(arr)))
                assert arr.ndim == 2, \
                    f"segment {name!r} must be stacked (N, W), got {arr.shape}"
                raw = arr.view(np.uint8).reshape(arr.shape[0], -1)
                row_crcs = [zlib.crc32(raw[r].tobytes())
                            for r in range(raw.shape[0])]
                path = os.path.join(tmp, f"seg_{_safe(name)}.bin")
                data = raw.tobytes()
                with open(path, "wb") as f:
                    f.write(data)
                    f.flush()
                    os.fsync(f.fileno())
                manifest["segs"][name] = {
                    "dtype": str(arr.dtype), "shape": list(arr.shape),
                    "file": f"seg_{_safe(name)}.bin",
                    "row_crc32": row_crcs,
                    "file_crc32": zlib.crc32(data)}
                self.metrics["writes"] += 1
                self.metrics["write_bytes"] += len(data)
            manifest["manifest_crc32"] = _manifest_crc(manifest)
            with open(os.path.join(tmp, MANIFEST), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            _fsync_dir(tmp)
            self._drop_mmaps(key)              # maps hold the OLD inode
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)              # the commit point
            _fsync_dir(self.root)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._manifests[key] = manifest

    # -- verification ------------------------------------------------------
    def _read_manifest(self, key: str) -> Optional[dict]:
        try:
            with open(os.path.join(self.key_dir(key), MANIFEST)) as f:
                return json.load(f)
        except (OSError, ValueError):
            return None

    def _verify_open(self, key: str) -> Optional[dict]:
        """Full verification at open: manifest self-crc + whole-file
        crc32 of every segment (a torn or truncated write surfaces HERE,
        not as garbage rows mid-relay).  Returns the manifest or None."""
        manifest = self._read_manifest(key)
        if manifest is None or "segs" not in manifest:
            return None
        if manifest.get("manifest_crc32") != _manifest_crc(manifest):
            return None
        for name, meta in manifest["segs"].items():
            path = self.seg_path(key, name)
            try:
                with open(path, "rb") as f:
                    if zlib.crc32(f.read()) != meta["file_crc32"]:
                        return None
            except OSError:
                return None
        return manifest

    def open(self, key: str) -> dict:
        """Verified manifest for ``key`` (cached until ``put``/heal);
        a failing segment is quarantined and rebuilt."""
        cached = self._manifests.get(key)
        if cached is not None:
            return cached
        manifest = self._verify_open(key)
        if manifest is None:
            self._heal(key, f"segment {key!r} failed open-time verification")
            manifest = self._verify_open(key)
            if manifest is None:
                raise TierIntegrityError(
                    f"segment {key!r} still fails verification after rebuild")
        self._manifests[key] = manifest
        return manifest

    def step(self, key: str) -> int:
        return int(self.open(key)["step"])

    # -- healing -----------------------------------------------------------
    def _heal(self, key: str, reason: str) -> None:
        """Quarantine the damaged segment directory and rebuild it from
        the authoritative source (newest good checkpoint)."""
        self._manifests.pop(key, None)
        self._drop_mmaps(key)
        kdir = self.key_dir(key)
        if os.path.isdir(kdir):
            qroot = os.path.join(self.root, QUARANTINE)
            os.makedirs(qroot, exist_ok=True)
            dest = os.path.join(
                qroot, f"{_safe(key)}.{self.metrics['quarantined']}")
            shutil.rmtree(dest, ignore_errors=True)
            os.rename(kdir, dest)
            self.metrics["quarantined"] += 1
        if self.rebuilder is None:
            raise TierIntegrityError(
                f"{reason} and no rebuilder is attached "
                f"(no checkpoint source — cannot self-heal)")
        self.rebuilder(key)
        self.metrics["rebuilt_segments"] += 1

    # -- read path ---------------------------------------------------------
    def _pread(self, path: str, offset: int, length: int) -> bytes:
        if self.fault_hook is not None:
            self.fault_hook(path, offset, length)
        with open(path, "rb") as f:
            f.seek(offset)
            data = f.read(length)
        if len(data) != length:
            raise OSError(errno.EIO,
                          f"short read: {len(data)}/{length} at "
                          f"{path}:{offset}")
        return data

    def _ensure_mmap(self, path: str):
        m = self._mmaps.get(path)
        if m is None:
            with open(path, "rb") as f:
                m = _mmap.mmap(f.fileno(), 0, access=_mmap.ACCESS_READ)
            self._mmaps[path] = m
        return m

    def _mread(self, path: str, offset: int, length: int):
        """Zero-copy span over the mmapped segment file: no userspace
        buffer — the returned memoryview windows the page cache, and crc
        verification + np.frombuffer run directly over it."""
        if self.fault_hook is not None:
            self.fault_hook(path, offset, length)
        m = self._ensure_mmap(path)
        if offset + length > len(m):
            raise OSError(errno.EIO,
                          f"short map: {len(m)}/{offset + length} at {path}")
        return memoryview(m)[offset:offset + length]

    def _drop_mmaps(self, key: str) -> None:
        """Invalidate cached maps under a key's directory: put/_heal
        rename the directory, so a cached map holds the OLD inode's
        bytes.  Maps still pinned by exported row views are dropped
        without closing (the view keeps the old map alive until the
        consumer lets go; it never aliases the new file)."""
        prefix = self.key_dir(key) + os.sep
        for path in [p for p in self._mmaps if p.startswith(prefix)]:
            m = self._mmaps.pop(path)
            try:
                m.close()
            except BufferError:
                pass

    def _retry(self, reader, path: str, offset: int, length: int):
        """Bounded retry with exponential backoff on transient errors;
        non-transient errnos and an exhausted budget raise TierReadError."""
        delay = self.backoff_s
        for attempt in range(self.retries + 1):
            try:
                return reader(path, offset, length)
            except OSError as e:
                if e.errno not in _TRANSIENT or attempt == self.retries:
                    raise TierReadError(
                        f"read of {path}:{offset}+{length} failed after "
                        f"{attempt + 1} attempt(s): {e}") from e
                self.metrics["retries"] += 1
                time.sleep(delay)
                delay *= 2
        raise AssertionError("unreachable")

    def _pread_retry(self, path: str, offset: int, length: int) -> bytes:
        return self._retry(self._pread, path, offset, length)

    def _read_span(self, path: str, offset: int, length: int):
        """One verified-read span: the mmap view where available (with
        the same transient-retry semantics — the fault seam fires on
        both paths), pread bytes otherwise."""
        if self.use_mmap:
            try:
                self._ensure_mmap(path)
            except (OSError, ValueError):
                pass   # mmap unavailable for this file: pread the span
            else:
                out = self._retry(self._mread, path, offset, length)
                self.metrics["mmap_reads"] += 1
                return out
        data = self._pread_retry(path, offset, length)
        self.metrics["pread_reads"] += 1
        return data

    def read_rows(self, key: str, lo: int, hi: int, *, copy: bool = True,
                  _healed: bool = False) -> Dict[str, np.ndarray]:
        """Rows [lo, hi) of every segment of ``key`` — one contiguous
        span per segment (a zero-copy mmap view where available, one
        pread otherwise), each row's crc32 verified against the manifest
        before the bytes are trusted.  A checksum failure quarantines +
        rebuilds the segment and retries the read once.

        ``copy=False`` returns read-only views over the mapped file on
        the mmap path (no userspace copy at all) — for streaming
        consumers that repack the rows immediately; the views alias the
        file, so they must not be held across a later ``put``/rot."""
        manifest = self.open(key)
        out: Dict[str, np.ndarray] = {}
        for name, meta in manifest["segs"].items():
            n, w = meta["shape"]
            assert 0 <= lo <= hi <= n, f"rows [{lo}, {hi}) out of (0, {n})"
            dt = _np_dtype(meta["dtype"])
            row_bytes = w * dt.itemsize
            data = self._read_span(self.seg_path(key, name),
                                   lo * row_bytes, (hi - lo) * row_bytes)
            self.metrics["reads"] += 1
            self.metrics["read_bytes"] += len(data)
            for r in range(hi - lo):
                chunk = data[r * row_bytes:(r + 1) * row_bytes]
                if zlib.crc32(chunk) != meta["row_crc32"][lo + r]:
                    if _healed:
                        raise TierIntegrityError(
                            f"segment {key}/{name} row {lo + r} still "
                            f"corrupt after rebuild")
                    self._heal(key, f"segment {key}/{name} row {lo + r} "
                               f"failed its crc32 at read time")
                    return self.read_rows(key, lo, hi, copy=copy,
                                          _healed=True)
            arr = np.frombuffer(data, dtype=dt).reshape(hi - lo, w)
            if copy and isinstance(data, memoryview):
                arr = arr.copy()       # detach from the mapped file
            out[name] = arr
        return out


# ===========================================================================
# Demotion planning (shared with core.memory_model's tier accounting)
# ===========================================================================
def demote_plan(per_layer_bytes: List[int], n_layers: List[int],
                host_budget: int) -> List[int]:
    """Hot (host-resident) row count per group under ``host_budget``.

    Rows are demoted coldest-first: last group's last rows first, walking
    toward group 0, until the resident stacked state fits the budget.
    ``host_budget <= 0`` demotes everything (the fully-streamed mode); a
    budget larger than the total demotes nothing.  This is THE demotion
    policy — ``TierChain`` executes it and ``memory_model.estimate``
    accounts it, so the two can never drift."""
    assert len(per_layer_bytes) == len(n_layers)
    if host_budget <= 0:
        return [0] * len(n_layers)
    hot = list(n_layers)
    resident = sum(b * n for b, n in zip(per_layer_bytes, n_layers))
    for gi in range(len(n_layers) - 1, -1, -1):
        if resident <= host_budget:
            break
        over = resident - host_budget
        drop = min(hot[gi], -(-over // max(per_layer_bytes[gi], 1)))
        hot[gi] -= drop
        resident -= drop * per_layer_bytes[gi]
    return hot


def ring_depth(prefetch_depth: int, chunk_bytes: int, slack: int,
               bounded: bool) -> int:
    """Effective read-ahead depth of the disk prefetch ring: the
    configured ``prefetch_depth``, shrunk so the in-flight chunks fit the
    host-budget ``slack`` when the budget is ``bounded`` (the watchdog's
    arithmetic — shrink instead of OOM; never below 1 in-flight read)."""
    k = max(1, int(prefetch_depth))
    if not bounded or chunk_bytes <= 0:
        return k
    return max(1, min(k, slack // chunk_bytes))


# ===========================================================================
# Demoted placeholder — what a staged-out group looks like between steps
# ===========================================================================
@jax.tree_util.register_pytree_node_class
class Demoted:
    """Placeholder for a layer group whose cold row tail lives on disk.

    Holds the hot (resident) row prefix in the group's original layout
    (per-leaf pytree or ``packing.Packed``); the ``TierChain`` that
    created it re-materializes the full group before any jitted call.
    """
    __slots__ = ("hot", "group_index", "role", "n_total", "hot_rows")

    def __init__(self, hot: Any, group_index: int, role: str,
                 n_total: int, hot_rows: int):
        self.hot = hot
        self.group_index = group_index
        self.role = role
        self.n_total = n_total
        self.hot_rows = hot_rows

    def tree_flatten(self):
        return (self.hot,), (self.group_index, self.role,
                             self.n_total, self.hot_rows)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    def __repr__(self):
        return (f"Demoted(g{self.group_index}_{self.role}, "
                f"{self.hot_rows}/{self.n_total} rows hot)")


def is_demoted(x) -> bool:
    return isinstance(x, Demoted)


def _rows(tree, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], tree)


def _concat_rows(hot, cold):
    return jax.tree.map(lambda h, c: jnp.concatenate([h, c], axis=0),
                        hot, cold)


# ===========================================================================
# TierChain — HBM <- pinned host <- SegmentStore, around the jit boundary
# ===========================================================================
class TierChain:
    """Demote/re-materialize the stacked EPS state through a SegmentStore.

    The in-jit tiers (HBM <- pinned host) are ``eps.Placement``'s job;
    this adapter extends the chain below the process: between jitted
    calls the cold row tail of each layer group (weights + optimizer
    slots) lives ONLY in the store, and ``stage_in``/``stage_out`` move
    it across the disk boundary around every call — ``stage_in`` with a
    ``prefetch_depth``-deep read ring over ``layers_per_relay``-row
    chunks (the same stop schedule as the in-jit relay), ``stage_out``
    with crash-consistent verified writes.
    """

    def __init__(self, store: SegmentStore, *, host_budget: int = 0,
                 layers_per_relay: int = 1, prefetch_depth: int = 0,
                 opt_slots: Optional[Tuple[str, ...]] = None):
        self.store = store
        self.host_budget = int(host_budget)
        self.group = max(1, int(layers_per_relay))
        self.depth = max(0, int(prefetch_depth))
        self._wspecs: Dict[int, packing.PackSpec] = {}
        self._packed_groups = False
        self._step = 0
        self._ckpt: Optional[Tuple[str, str, Any]] = None  # (dir, prefix, eng)
        self._mat_cache: Optional[Tuple[int, Any]] = None
        self._demoted_layers = 0
        self._resident_bytes = 0
        # async read-ahead: adopt/stage_out schedule the NEXT window's
        # cold-segment fetches here; stage_in consumes them
        self._async_pool: Optional[ThreadPoolExecutor] = None
        self._prefetched: Dict[Tuple[str, int], Any] = {}

    # -- metrics ------------------------------------------------------------
    @property
    def metrics(self) -> Dict[str, int]:
        return {**self.store.metrics,
                "demoted_layers": self._demoted_layers,
                "resident_bytes": self._resident_bytes}

    # -- layout helpers ------------------------------------------------------
    @staticmethod
    def _key(gi: int, role: str) -> str:
        return f"g{gi}_{role}"

    def _group_segments(self, gi: int, group) -> Dict[str, np.ndarray]:
        """A params group (pytree or Packed) -> numpy flat segments;
        records the PackSpec used so cold rows can be rebuilt."""
        if packing.is_packed(group):
            self._packed_groups = True
            self._wspecs[gi] = group.spec
            return {k: np.asarray(jax.device_get(v))
                    for k, v in group.segs.items()}
        packed = packing.pack(group)
        self._wspecs[gi] = packed.spec
        return {k: np.asarray(jax.device_get(v))
                for k, v in packed.segs.items()}

    def _opt_segments(self, gi: int, g_opt) -> Dict[str, np.ndarray]:
        """An opt group ({leaf: {m, v}} pytree or {slot: Packed}) ->
        numpy segments keyed ``<slot>:<dtype>``; () slots -> {}."""
        if packing.opt_is_packed(g_opt):
            return {f"{s}:{k}": np.asarray(jax.device_get(v))
                    for s, p in g_opt.items() for k, v in p.segs.items()}
        spec = self._wspecs[gi]
        packed = packing.pack_opt(spec, g_opt)
        return {f"{s}:{k}": np.asarray(jax.device_get(v))
                for s, p in packed.items() for k, v in p.segs.items()}

    def _cold_group(self, gi: int, segs: Dict[str, np.ndarray]):
        """Disk rows -> a group-layout tree (Packed or per-leaf)."""
        packed = packing.Packed({k: jnp.asarray(v) for k, v in segs.items()},
                                self._wspecs[gi])
        return packed if self._packed_groups else packing.unpack(packed)

    def _cold_opt(self, gi: int, segs: Dict[str, np.ndarray]):
        slots: Dict[str, dict] = {}
        for name, arr in segs.items():
            slot, seg_key = name.split(":", 1)
            slots.setdefault(slot, {})[seg_key] = jnp.asarray(arr)
        spec = self._wspecs[gi]
        packed = {s: packing.Packed(d, spec) for s, d in sorted(slots.items())}
        if self._packed_groups:
            return packed
        return packing.unpack_opt(spec, packed)

    # -- adoption: write everything cold, wrap placeholders ------------------
    def adopt(self, state, step: Optional[int] = None):
        """Bring a fully-materialized TrainState under tier management:
        write every group's segments to the store, then demote the
        coldest row tail per the host budget (placeholders replace the
        demoted rows, so the host actually frees them)."""
        params, opt = state.params, state.opt_state
        self._step = int(state.step if step is None else step)
        groups = params["groups"]
        n_layers, per_layer = [], []
        for g_w, g_o in zip(groups, opt["groups"]):
            assert not (is_demoted(g_w) or is_demoted(g_o)), \
                "adopt/stage_out need a fully-materialized state"
            leaves = jax.tree.leaves(g_w)
            n = int(leaves[0].shape[0])
            gb = sum(a.nbytes for a in leaves) \
                + sum(a.nbytes for a in jax.tree.leaves(g_o))
            n_layers.append(n)
            per_layer.append(gb // max(n, 1))
        hot = demote_plan(per_layer, n_layers, self.host_budget)
        new_w, new_o = [], []
        for gi, (g_w, g_o) in enumerate(zip(groups, opt["groups"])):
            if hot[gi] >= n_layers[gi]:
                new_w.append(g_w)
                new_o.append(g_o)
                continue
            w_segs = self._group_segments(gi, g_w)
            o_segs = self._opt_segments(gi, g_o)
            self.store.put(self._key(gi, "w"), w_segs, self._step)
            if o_segs:
                self.store.put(self._key(gi, "opt"), o_segs, self._step)
            new_w.append(Demoted(_rows(g_w, 0, hot[gi]), gi, "w",
                                 n_layers[gi], hot[gi]))
            new_o.append(Demoted(_rows(g_o, 0, hot[gi]), gi, "opt",
                                 n_layers[gi], hot[gi])
                         if o_segs else g_o)
        self._mat_cache = None
        self._demoted_layers = sum(n - h for n, h in zip(n_layers, hot))
        self._resident_bytes = sum(b * h
                                   for b, h in zip(per_layer, hot))
        self._schedule_async(new_w + new_o)
        return state.replace(
            params={**params, "groups": tuple(new_w)},
            opt_state={**opt, "groups": tuple(new_o)})

    # -- stage in: disk -> host ----------------------------------------------
    def _fetch_cold(self, d: Demoted) -> Dict[str, np.ndarray]:
        """Read a placeholder's cold rows chunk-by-chunk with the
        prefetch ring: chunks are ``layers_per_relay`` rows (the relay's
        own stop schedule), up to ``effective_depth`` reads in flight.
        The watchdog shrinks the depth when the budget slack cannot hold
        the in-flight chunks — degrade, don't OOM."""
        key = self._key(d.group_index, d.role)
        manifest = self.store.open(key)
        bounds = stop_bounds(d.n_total - d.hot_rows, self.group,
                             start=d.hot_rows)
        row_bytes = sum(m["shape"][1] * _np_dtype(m["dtype"]).itemsize
                        for m in manifest["segs"].values())
        chunk_bytes = self.group * row_bytes
        hot_bytes = sum(
            a.nbytes for a in jax.tree.leaves(d.hot)) if d.hot_rows else 0
        slack = max(self.host_budget - hot_bytes, 0)
        eff = ring_depth(self.depth, chunk_bytes, slack,
                         bounded=self.host_budget > 0)
        if self.depth >= 1 and eff < self.depth:
            self.store.metrics["prefetch_shrinks"] += 1
        self.store.metrics["effective_depth"] = eff
        # copy=False: the rows are concatenated (copied) right below, so
        # the mmap views never outlive this call
        if self.depth == 0 or len(bounds) <= 1:
            chunks = [self.store.read_rows(key, lo, hi, copy=False)
                      for lo, hi in bounds]
        else:
            with ThreadPoolExecutor(max_workers=eff) as pool:
                futs = [pool.submit(self.store.read_rows, key, lo, hi,
                                    copy=False)
                        for lo, hi in bounds]
                chunks = [f.result() for f in futs]
        return {name: np.concatenate([c[name] for c in chunks], axis=0)
                for name in manifest["segs"]}

    # -- async read-ahead: stage the next window before it is asked for ----
    def _pool(self) -> ThreadPoolExecutor:
        if self._async_pool is None:
            # one background lane: _fetch_cold parallelizes its own
            # chunk reads with the ring, so a second lane would only
            # fight it for the budget slack the watchdog protects
            self._async_pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="tier-stage")
        return self._async_pool

    def _schedule_async(self, groups) -> None:
        """Kick off the next relay window's cold-segment stage-in for
        every freshly-demoted group, so the disk reads overlap whatever
        runs between this stage_out and the next stage_in (the jitted
        step's host-side tail included).  Ring-gated: depth 0 means the
        caller asked for strictly synchronous staging."""
        for fut in self._prefetched.values():
            fut.cancel()
        self._prefetched = {}
        if self.depth < 1:
            return
        for d in groups:
            if is_demoted(d) and d.hot_rows < d.n_total:
                self._prefetched[
                    (self._key(d.group_index, d.role), self._step)
                ] = self._pool().submit(self._fetch_cold, d)

    def _materialize_group(self, d: Demoted):
        fut = self._prefetched.pop(
            (self._key(d.group_index, d.role), self._step), None)
        segs = None
        if fut is not None:
            try:
                segs = fut.result()
                self.store.metrics["async_stage_hits"] += 1
            except TierError:
                raise
            except Exception:
                segs = None            # stale future: fetch synchronously
        elif self.depth >= 1:
            self.store.metrics["async_stage_misses"] += 1
        if segs is None:
            segs = self._fetch_cold(d)
        cold = (self._cold_group(d.group_index, segs) if d.role == "w"
                else self._cold_opt(d.group_index, segs))
        return cold if d.hot_rows == 0 else _concat_rows(d.hot, cold)

    def materialize_params(self, params):
        """Params with every Demoted group re-materialized (read-only:
        nothing is written back).  Cached by tuple identity so a serving
        loop re-reads the disk tier once per staged-out state, not once
        per decode token."""
        groups = params["groups"]
        if not any(is_demoted(g) for g in groups):
            return params
        if self._mat_cache is not None and self._mat_cache[0] is groups:
            return self._mat_cache[1]
        full = tuple(self._materialize_group(g) if is_demoted(g) else g
                     for g in groups)
        out = {**params, "groups": full}
        self._mat_cache = (groups, out)
        return out

    def stage_in(self, state):
        """Re-materialize every demoted group (weights + opt) — the
        disk->host relay that runs before each jitted step."""
        params = self.materialize_params(state.params)
        opt = state.opt_state
        o_groups = tuple(self._materialize_group(g) if is_demoted(g) else g
                         for g in opt["groups"])
        return state.replace(params=params,
                             opt_state={**opt, "groups": o_groups})

    # -- stage out: host -> disk ---------------------------------------------
    def stage_out(self, state):
        """Write the demoted groups' (updated) segments back to the
        store — verified, crash-consistent — and drop the cold rows from
        host memory again.  The store's ``step`` advances with the
        state, so a later ``save`` at the same step is a valid rebuild
        source."""
        return self.adopt(state)

    # -- checkpoint-backed self-healing --------------------------------------
    def attach_checkpoints(self, directory: str, prefix: str,
                           engine) -> None:
        """Install the quarantine-rebuild source: the newest good
        snapshot in ``directory``.  Its step must match the store's
        (stage_out runs before save in the engine, so a save at step s
        makes every segment at step s rebuildable)."""
        self._ckpt = (directory, prefix, engine)
        self.store.rebuilder = self._rebuild

    def _rebuild(self, key: str) -> None:
        from repro.checkpoint import io as ckpt_io
        assert self._ckpt is not None
        directory, prefix, engine = self._ckpt
        m = re.fullmatch(r"g(\d+)_(w|opt)", key)
        assert m, f"unrecognized segment key {key!r}"
        gi, role = int(m.group(1)), m.group(2)
        fp = engine.state_fingerprint()
        step = ckpt_io.latest_good(directory, prefix, fingerprint=fp)
        if step is None:
            raise TierIntegrityError(
                f"cannot rebuild {key!r}: no good checkpoint in "
                f"{directory}")
        if step != self._step:
            raise TierIntegrityError(
                f"cannot rebuild {key!r}: newest good checkpoint is step "
                f"{step} but the store holds step {self._step} bytes")
        like = engine.abstract_state()
        like_p, like_o = like.params, like.legacy_opt()
        if self._packed_groups:
            like_o = jax.eval_shape(packing.unpack_opt_state, like_o, like_p)
            like_p = jax.eval_shape(packing.unpack_params, like_p)
        params, opt, _ = ckpt_io.restore_train_state(
            directory, like_p, like_o, step=step, prefix=prefix,
            fingerprint=fp)
        if self._packed_groups:
            params = packing.pack_params(params)
            opt = packing.pack_opt_state(opt, params)
        # weights first even for an opt rebuild: _opt_segments needs the
        # group's PackSpec, which _group_segments records
        segs = self._group_segments(gi, params["groups"][gi])
        if role != "w":
            segs = self._opt_segments(gi, opt["groups"][gi])
        self.store.put(key, segs, step)
