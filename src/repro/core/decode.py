"""Serving engine: one-token decode steps against per-layer caches.

The L2L idea applies to inference too: with ``weight_stream`` the model
lives in pinned_host and the decode scan relays one layer's weights at a
time — a 314B Grok fits a 16GB device the same way a 96-layer BERT did in
the paper's Table 2.

``serve_step`` lowers for the decode input shapes (decode_32k, long_500k).
For long-context decode the cache is a ring buffer of ``window`` slots
(sliding-window attention); SSM/hybrid archs carry their O(1) recurrent
state instead.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.eps import EPSPlacements, make_placements
from repro.core.relay import Stream, relay_scan
from repro.core.schedule import ExecutionConfig
from repro.models.common import materialize, abstract


def make_serve_step(model, exec_cfg: ExecutionConfig,
                    placements: Optional[EPSPlacements] = None) -> Callable:
    """Returns serve_step(params, caches, token, cur_pos) ->
    (logits, new_caches).

    ``caches``: tuple over decode groups of stacked per-layer cache trees.
    ``token``: (B, T) int32 (T = 1 historically);  ``cur_pos``: scalar
    int32 absolute position, or per-row (B,)/(B,T) positions (continuous
    batching — each batch slot decodes at its own offset; negative
    positions mark padding/inactive rows whose cache writes are dropped
    and whose outputs are garbage to be ignored).  The scalar single-token
    form emits the historical program byte-for-byte.

    The serving weight relay (EPS streaming, prefetch ring, packed slots,
    G-layer groups) is the same ``relay_scan`` the training scans use:
    with ``prefetch_depth >= 1`` the next slot's weights stream from the
    EPS while the current layers attend against their caches.
    """
    if placements is None:
        placements = make_placements(exec_cfg, len(model.groups))
    PF = exec_cfg.prefetch_depth
    PK = exec_cfg.pack_params
    G = exec_cfg.layers_per_relay
    TR = exec_cfg.transport
    DYN = exec_cfg.dynamic_depth
    if DYN:
        assert len(model.groups) == 1, \
            "dynamic_depth supports single-group models"

    dgroups = model.decode_groups()
    # map decode-group index -> model group index (for placements)
    gidx = [i for i, g in enumerate(model.groups) if not g.is_encoder]

    def serve_step(params, caches, token, cur_pos, n_active=None):
        if DYN:
            assert n_active is not None, \
                "dynamic_depth: decode takes a traced n_layers operand"
            act_win = (jnp.int32(0), jnp.asarray(n_active, jnp.int32))
        else:
            assert n_active is None, \
                "n_layers operand needs ExecutionConfig.dynamic_depth"
            act_win = None
        static = {"embed": params["embed"], "head": params["head"]}
        x = model.decode_embed(static, token, cur_pos)
        ctx = model.decode_ctx(cur_pos, window=exec_cfg.decode_window)
        new_caches = []
        for di, group in enumerate(dgroups):
            wp = placements.weights[gidx[di]]

            def body(x_c, slots, cache_l, _g=group):
                (w,) = slots
                if PK:
                    w = packing.unpack(w)
                x2, cache2 = _g.decode(w, x_c, cache_l, None, ctx)
                return x2, cache2

            idle = None
            if DYN:
                def idle(x_c, slots, cache_l):
                    # inactive layer: hidden state AND cache untouched
                    return x_c, cache_l

            x, nc = relay_scan(
                body, x, (Stream(wp, params["groups"][gidx[di]]),),
                xs=caches[di], group=G, prefetch=PF,
                unroll=exec_cfg.unroll_layers, transport=TR,
                active=act_win, idle_body=idle)
            new_caches.append(nc)
        logits = model.decode_logits(static, x)
        return logits, tuple(new_caches)

    return serve_step


def init_caches(model, batch: int, live_seq: int, rng=None,
                abstract_only: bool = False, dtype=None):
    """Build (or abstractly describe) the stacked decode caches."""
    dtype = dtype or jnp.dtype(model.cfg.dtype)
    specs = model.cache_specs(batch, live_seq)

    def conv(spec):
        if abstract_only:
            return abstract(spec, dtype)
        return materialize(spec, rng or jax.random.PRNGKey(0), dtype)

    out = []
    for spec in specs:
        tree = conv(spec)
        # position slots must be int32 and start invalid (-1)
        def fix(path_leaf, leaf):
            return leaf
        tree = _fix_pos(tree, abstract_only)
        out.append(tree)
    return tuple(out)


def _fix_pos(tree, abstract_only):
    """Replace 'pos' leaves with int32 arrays initialized to -1 (invalid)."""
    def walk(t):
        if isinstance(t, dict):
            out = {}
            for k, v in t.items():
                if k == "pos":
                    if abstract_only:
                        out[k] = jax.ShapeDtypeStruct(v.shape, jnp.int32)
                    else:
                        out[k] = -jnp.ones(v.shape, jnp.int32)
                else:
                    out[k] = walk(v)
            return out
        return t
    return walk(tree)


def prefill(model, params, tokens, live_seq: int,
            exec_cfg: Optional[ExecutionConfig] = None,
            frames=None, n_layers=None):
    """Build caches by feeding the prompt one token at a time through
    ``serve_step`` (works uniformly for every family: KV, ring-buffer,
    MLA-compressed, SSM state).  Returns (caches, last_logits).

    For whisper, pass ``frames`` — the encoder runs once and its projected
    cross-attention K/V are written into the decoder caches first.
    With ``exec_cfg.dynamic_depth``, ``n_layers`` (default capacity) is
    the runtime depth forwarded to every serve step.
    """
    exec_cfg = exec_cfg or ExecutionConfig()
    B, S = tokens.shape
    caches = init_caches(model, B, live_seq)
    if model.cfg.family == "audio":
        assert frames is not None
        caches = encode_cross_kv(model, params, frames, caches)
    serve = make_serve_step(model, exec_cfg)
    n_op = None
    if exec_cfg.dynamic_depth:
        cap = sum(g.n_layers for g in model.groups)
        n_op = jnp.asarray(cap if n_layers is None else n_layers,
                           jnp.int32)

    def body(carry, i):
        caches = carry
        tok = jax.lax.dynamic_slice_in_dim(tokens, i, 1, axis=1)
        if n_op is None:
            logits, caches = serve(params, caches, tok, i)
        else:
            logits, caches = serve(params, caches, tok, i, n_op)
        return caches, logits[:, 0]

    caches, logits = jax.lax.scan(body, caches, jnp.arange(S, dtype=jnp.int32))
    return caches, logits[-1]


def encode_cross_kv(model, params, frames, caches):
    """Run the whisper encoder once and fill the decoder caches' xk/xv."""
    from repro.models.common import apply_norm
    # this one-shot pass walks the param tree by name — view packed groups
    # through their unpacked layout
    params = packing.unpack_params(params)
    cfg = model.cfg
    static = {"embed": params["embed"], "head": params["head"]}
    batch = {"frames": frames}
    x, _ = model.prepare(static, batch)
    enc = model.groups[0]
    ctx = model.train_ctx(batch, enc)

    def body(h, w):
        h2, _ = enc.apply(w, h, None, ctx)
        return h2, None

    x, _ = jax.lax.scan(body, x, params["groups"][0])
    mem = apply_norm(static["embed"]["enc_ln_post"], x, cfg.norm_eps)

    def layer_kv(w):
        dt = mem.dtype
        k = jnp.einsum("bsd,dke->bske", mem, w["xattn"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dke->bske", mem, w["xattn"]["wv"].astype(dt))
        if "bk" in w["xattn"]:
            k = k + w["xattn"]["bk"].astype(dt)
            v = v + w["xattn"]["bv"].astype(dt)
        return k, v

    # decoder is the last group / only decode group
    dec_idx = len(caches) - 1
    xk, xv = jax.vmap(layer_kv)(params["groups"][-1])
    new_dec = dict(caches[dec_idx])
    new_dec["xk"] = xk.astype(caches[dec_idx]["xk"].dtype)
    new_dec["xv"] = xv.astype(caches[dec_idx]["xv"].dtype)
    return tuple(list(caches[:dec_idx]) + [new_dec])
