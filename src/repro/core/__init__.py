from repro.core.schedule import ExecutionConfig
from repro.core import l2l, baseline, decode, eps

__all__ = ["ExecutionConfig", "l2l", "baseline", "decode", "eps"]
