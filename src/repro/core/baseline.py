"""Baseline execution engines — Algorithms 1 and 2 of the paper.

Algorithm 1: whole minibatch, whole model resident, grad + update.
Algorithm 2: microbatch loop with gradient accumulation, then update.
Both optionally rematerialize per layer (``exec_cfg.remat``) — the paper's
"even assuming the baseline also recomputes to save memory" comparison.

These are the reference against which the L2L engine's gradients are
asserted bit-comparable (Fig 3/4's learning-curve equivalence claim).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.schedule import ExecutionConfig
from repro.optim import Optimizer, clip_by_norm, tree_global_norm


def make_loss_fn(model, remat: bool = False) -> Callable:
    def loss_fn(params, batch):
        loss, (loss_sum, wsum, aux) = model.full_loss(params, batch,
                                                      remat=remat)
        return loss, (loss_sum, wsum, aux)
    return loss_fn


def make_grads_fn(model, exec_cfg: ExecutionConfig) -> Callable:
    """(params, batch) -> (loss, grads).  Algorithm 2 when
    n_microbatches > 1 (normalized like the L2L engine: sum of per-ub
    loss_sums / total weight + mean aux)."""
    UB = exec_cfg.n_microbatches

    def fn(params, batch):
        W_total = jnp.maximum(batch["mask"].sum(), 1.0)

        def ub_loss(params, b):
            loss, (loss_sum, wsum, aux) = model.full_loss(
                params, b, remat=exec_cfg.remat)
            return loss_sum / W_total + aux / UB, loss_sum

        if UB == 1:
            (l, ls), g = jax.value_and_grad(ub_loss, has_aux=True)(
                params, batch)
            return l, g

        batch_ub = jax.tree.map(
            lambda a: a.reshape(UB, a.shape[0] // UB, *a.shape[1:]), batch)

        def body(carry, b):
            loss_acc, g_acc = carry
            (l, _), g = jax.value_and_grad(ub_loss, has_aux=True)(params, b)
            g_acc = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32), g_acc, g)
            return (loss_acc + l, g_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zeros),
                                        batch_ub)
        return loss, grads

    return fn


def make_train_step(model, optimizer: Optimizer, exec_cfg: ExecutionConfig
                    ) -> Callable:
    """Algorithm 1 (UB=1) / Algorithm 2 (UB>1): monolithic update at the
    end of the minibatch (the paper's Fig 1b)."""
    grads_fn = make_grads_fn(model, exec_cfg)

    def step(params, opt_state, batch):
        loss, grads = grads_fn(params, batch)
        gnorm = tree_global_norm(grads)
        finite = jnp.all(jnp.stack([
            jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(grads)]))
        if exec_cfg.clip_mode == "per_layer":
            # match L2L's per-layer clip semantics: clip each stacked layer
            # group leaf-tree independently is layer-wise only for stacked
            # params; here we clip the whole tree per group for parity.
            clipped_groups = []
            for g in grads["groups"]:
                cg, _ = clip_by_norm(g, exec_cfg.clip_norm)
                clipped_groups.append(cg)
            grads = {**grads, "groups": tuple(clipped_groups)}
        new_params, new_inner = optimizer.update(
            grads,
            {"embed": opt_state["embed"], "head": opt_state["head"],
             "groups": opt_state["groups"]},
            params, opt_state["step"])
        new_opt = {"step": opt_state["step"] + 1, **{
            k: new_inner[k] for k in ("embed", "head", "groups")}}
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "weight_sum": batch["mask"].sum()}
        if exec_cfg.skip_nonfinite:
            # anomaly sentinel (same contract as the L2L engines): a
            # non-finite gradient rejects the whole step bit-identically
            # — params, opt slots and the step counter all unchanged.
            new_params = jax.tree.map(
                lambda n, o: jnp.where(finite, n, o), new_params, params)
            new_opt = {k: jax.tree.map(
                lambda n, o: jnp.where(finite, n, o),
                new_opt[k], opt_state[k])
                for k in ("step", "embed", "head", "groups")}
            metrics["skipped_steps"] = jnp.where(finite, 0, 1).astype(
                jnp.int32)
        return new_params, new_opt, metrics

    return step


def init_opt_state(optimizer: Optimizer, params) -> dict:
    return {
        "step": jnp.zeros((), jnp.int32),
        "embed": optimizer.init(params["embed"]),
        "head": optimizer.init(params["head"]),
        "groups": tuple(optimizer.init(g) for g in params["groups"]),
    }
