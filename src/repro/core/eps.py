"""Eager Param-Server (EPS): tier-chain memory placement.

The paper's EPS is a host process owning the model + optimizer state,
relaying layers to the device and eagerly reducing/optimizing.  The
placement is a tier CHAIN — device HBM <- pinned host <- mmap/NVMe:

* the two IN-JIT tiers are XLA memory spaces, ``pinned_host`` (host DRAM
  behind the chip's DMA engines) and ``device`` (HBM); a ``Placement``
  bundles the device_put helpers the L2L scans use,
* the third tier sits BELOW the process: with ``ExecutionConfig.tiers=3``
  the cold tail of the stacked state lives in a verified on-disk
  ``core.tierstore.SegmentStore`` and crosses the disk boundary around
  each jitted call (``TierChain.stage_in``/``stage_out``), under a host
  byte budget.  ``EPSPlacements.disk`` carries that tier's static spec
  (``TierChainSpec``); the live store/chain is built by the Engine.

``Placement`` helpers:

* ``host(tree)``       — put a pytree into pinned_host, preserving sharding
* ``dev(tree)``        — fetch into device HBM (the per-layer "relay")
* ``dev_grouped(tree)`` — fetch a G-layer relay SLOT (leading stop axis)
  into HBM; on a mesh the layer-slice pspecs shift one dim right
  (``P(None, *spec)``), elsewhere it is ``dev``.

This module only builds placements; the scan-level relay logic — which
layer/group a slot holds, how many DMAs are in flight — lives entirely in
``repro.core.relay`` (the one module issuing relay DMAs), and the disk
tier's staging/healing in ``repro.core.tierstore``.

Shardings are explicit NamedShardings derived from the param/activation
PartitionSpecs because ``jax.device_put`` inside jit needs a concrete
sharding (memory-kind-only transfers still re-state the spec).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P, SingleDeviceSharding


class Placement(NamedTuple):
    host: Callable                           # tree -> tree (pinned_host)
    dev: Callable                            # tree -> tree (device HBM)
    dev_grouped: Optional[Callable] = None   # G-layer slot -> device HBM
    enabled: bool = True


def noop_placement() -> Placement:
    ident = lambda t: t
    return Placement(ident, ident, ident, enabled=False)


def memories_supported() -> bool:
    """True when the backend honors memory-space transfers inside jit.

    Verified empirically: the CPU backend silently DROPS
    ``jax.device_put(x, <memory_kind>)`` during lowering (zero
    pinned_host/annotate ops in the StableHLO) and its SPMD partitioner
    rejects memory-kind output annotations.  On TPU the same program text
    lowers to host-offload annotate custom calls.  All placements degrade
    to no-ops on unsupported backends — the L2L schedule (loop inversion,
    recompute, eager updates) is unchanged; only the physical two-tier
    residency needs TPU.  See DESIGN.md and EXPERIMENTS.md §Dry-run.
    """
    return jax.default_backend() == "tpu"


def single_device_placement(device=None) -> Placement:
    """For single-host tests/benchmarks: one device, two memory spaces."""
    dev = device or jax.devices()[0]
    h = SingleDeviceSharding(dev, memory_kind="pinned_host")
    d = SingleDeviceSharding(dev, memory_kind="device")

    def to(tree, sh):
        return jax.tree.map(lambda a: jax.device_put(a, sh), tree)

    dev = lambda t: to(t, d)
    return Placement(lambda t: to(t, h), dev, dev)


def mesh_placement(mesh, pspec_tree) -> Placement:
    """Sharded placement: pspec_tree mirrors the trees that will be moved
    (or is a single P applied to every leaf).  ``dev_grouped`` moves a
    G-layer relay slot: the per-layer-slice specs apply one dim to the
    right of the (never sharded) leading stop axis."""

    def build(tree, kind, lead=False):
        def one(a, spec):
            if lead:
                spec = P(None, *spec)
            sh = NamedSharding(mesh, spec, memory_kind=kind)
            return jax.device_put(a, sh)
        if isinstance(pspec_tree, P):
            return jax.tree.map(lambda a: one(a, pspec_tree), tree)
        return jax.tree.map(one, tree, pspec_tree)

    return Placement(lambda t: build(t, "pinned_host"),
                     lambda t: build(t, "device"),
                     lambda t: build(t, "device", lead=True))


class TierChainSpec(NamedTuple):
    """Static config of the third (disk) tier — HBM <- host <- THIS.

    Built from an ExecutionConfig by ``tier_spec``; the Engine turns it
    into a live ``core.tierstore.SegmentStore`` + ``TierChain``.  Unlike
    the memory-space tiers this one works on EVERY backend: it is host
    numpy I/O around the jit boundary, not an in-program annotation."""
    host_budget: int         # resident stacked-state byte budget (0 = none:
                             # demote everything — the fully-streamed mode)
    directory: str           # segment-store root ("" = engine temp dir)
    retries: int             # transient-read retry budget
    backoff_s: float         # initial exponential-backoff delay


def tier_spec(exec_cfg) -> Optional[TierChainSpec]:
    """The disk-tier spec of an ExecutionConfig, or None for the
    historical two-tier placement (``tiers=2``)."""
    if getattr(exec_cfg, "tiers", 2) < 3:
        return None
    return TierChainSpec(
        host_budget=int(getattr(exec_cfg, "host_budget_bytes", 0)),
        directory=str(getattr(exec_cfg, "tier_dir", "")),
        retries=int(getattr(exec_cfg, "tier_retries", 3)),
        backoff_s=float(getattr(exec_cfg, "tier_backoff_s", 0.01)))


class EPSPlacements(NamedTuple):
    """Per-use-site placements for one training/serving setup.

    ``weights[g]`` / ``opts[g]`` move one relay slot of group g (a layer
    slice, or a G-layer sub-stack via ``dev_grouped``); ``stash`` moves
    boundary-activation trees (a single P is broadcast to every leaf).
    The slot schedule itself (prefetch ring, layer groups) is
    ``repro.core.relay``'s job.  ``disk`` extends the chain below host
    DRAM (``tiers=3``): the static ``TierChainSpec`` of the verified
    NVMe segment store, or None for the two-tier placement."""
    weights: tuple           # tuple[Placement], one per layer group
    opts: tuple              # tuple[Placement], one per layer group
    stash: Placement
    disk: Optional[TierChainSpec] = None


def pspecs_like(pspec_tree, target_tree):
    """Broadcast a param-shaped pspec tree onto a state tree whose leaves
    replace each param leaf with a subtree of same-shaped arrays (adam m/v)."""
    is_p = lambda x: isinstance(x, P)
    flat_p, treedef = jax.tree.flatten(pspec_tree, is_leaf=is_p)
    flat_t = treedef.flatten_up_to(target_tree)
    out = [jax.tree.map(lambda _, _p=p: _p, t) for p, t in zip(flat_p, flat_t)]
    return jax.tree.unflatten(treedef, out)


def make_placements(exec_cfg, n_groups: int, mesh=None,
                    weight_pspecs=None, opt_pspecs=None,
                    stash_pspec=None) -> EPSPlacements:
    """Single-device (tests/benchmarks) or mesh-sharded placements.

    ``weight_pspecs``/``opt_pspecs``: per-group pspec trees for one layer
    slice; required when mesh is given and streaming is on."""
    noop = noop_placement()
    disk = tier_spec(exec_cfg)
    if not memories_supported():
        # backend drops memory-space transfers inside jit (CPU): the two
        # IN-JIT tiers become logical-only; the L2L schedule itself is
        # unchanged.  The disk tier is host-side I/O around the jit
        # boundary, so it stays PHYSICAL on every backend.
        return EPSPlacements((noop,) * n_groups, (noop,) * n_groups, noop,
                             disk)
    if mesh is None:
        single = single_device_placement()
        w = single if exec_cfg.weight_stream else noop
        s = single if exec_cfg.offload_stash else noop
        return EPSPlacements((w,) * n_groups, (w,) * n_groups, s, disk)
    ws = tuple(mesh_placement(mesh, weight_pspecs[g]) for g in range(n_groups)) \
        if exec_cfg.weight_stream else (noop,) * n_groups
    os_ = tuple(mesh_placement(mesh, opt_pspecs[g]) for g in range(n_groups)) \
        if exec_cfg.weight_stream else (noop,) * n_groups
    st = mesh_placement(mesh, stash_pspec if stash_pspec is not None else P()) \
        if exec_cfg.offload_stash else noop
    return EPSPlacements(ws, os_, st, disk)
