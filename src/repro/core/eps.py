"""Eager Param-Server (EPS): two-tier memory placement.

The paper's EPS is a host process owning the model + optimizer state,
relaying layers to the device and eagerly reducing/optimizing.  On TPU the
two tiers are XLA memory spaces: ``pinned_host`` (host DRAM behind the
chip's DMA engines) and ``device`` (HBM).  A ``Placement`` bundles the
device_put helpers the L2L scans use:

* ``host(tree)``   — put a pytree into pinned_host, preserving sharding
* ``dev(tree)``    — fetch into device HBM (the per-layer "relay")

Shardings are explicit NamedShardings derived from the param/activation
PartitionSpecs because ``jax.device_put`` inside jit needs a concrete
sharding (memory-kind-only transfers still re-state the spec).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P, SingleDeviceSharding


class Placement(NamedTuple):
    host: Callable   # tree -> tree (pinned_host)
    dev: Callable    # tree -> tree (device HBM)
    enabled: bool = True


def noop_placement() -> Placement:
    ident = lambda t: t
    return Placement(ident, ident, enabled=False)


def memories_supported() -> bool:
    """True when the backend honors memory-space transfers inside jit.

    Verified empirically: the CPU backend silently DROPS
    ``jax.device_put(x, <memory_kind>)`` during lowering (zero
    pinned_host/annotate ops in the StableHLO) and its SPMD partitioner
    rejects memory-kind output annotations.  On TPU the same program text
    lowers to host-offload annotate custom calls.  All placements degrade
    to no-ops on unsupported backends — the L2L schedule (loop inversion,
    recompute, eager updates) is unchanged; only the physical two-tier
    residency needs TPU.  See DESIGN.md and EXPERIMENTS.md §Dry-run.
    """
    return jax.default_backend() == "tpu"


def single_device_placement(device=None) -> Placement:
    """For single-host tests/benchmarks: one device, two memory spaces."""
    dev = device or jax.devices()[0]
    h = SingleDeviceSharding(dev, memory_kind="pinned_host")
    d = SingleDeviceSharding(dev, memory_kind="device")

    def to(tree, sh):
        return jax.tree.map(lambda a: jax.device_put(a, sh), tree)

    return Placement(lambda t: to(t, h), lambda t: to(t, d))


def mesh_placement(mesh, pspec_tree) -> Placement:
    """Sharded placement: pspec_tree mirrors the trees that will be moved
    (or is a single P applied to every leaf)."""

    def build(tree, kind):
        def one(a, spec):
            sh = NamedSharding(mesh, spec, memory_kind=kind)
            return jax.device_put(a, sh)
        if isinstance(pspec_tree, P):
            return jax.tree.map(lambda a: one(a, pspec_tree), tree)
        return jax.tree.map(one, tree, pspec_tree)

    return Placement(lambda t: build(t, "pinned_host"),
                     lambda t: build(t, "device"))


class EPSPlacements(NamedTuple):
    """Per-use-site placements for one training/serving setup.

    ``weights[g]`` / ``opts[g]`` move one *layer slice* of group g (trees
    without the stacked leading axis); ``stash`` moves boundary-activation
    trees (a single P is broadcast to every leaf)."""
    weights: tuple           # tuple[Placement], one per layer group
    opts: tuple              # tuple[Placement], one per layer group
    stash: Placement

    def relay(self, gi: int, stacked, *, reverse: bool = False,
              opt_stacked=None):
        """Two-slot (double-buffered) view over group ``gi``'s stacked
        host-resident trees — the ``prefetch_depth=1`` relay."""
        opt_relay = (Relay(self.opts[gi], opt_stacked, reverse=reverse)
                     if opt_stacked is not None else None)
        return Relay(self.weights[gi], stacked, reverse=reverse), opt_relay


# ---------------------------------------------------------------------------
# Double-buffered relay (prefetch_depth = 1)
# ---------------------------------------------------------------------------
def layer_slice(stacked, i):
    """Slice layer ``i`` out of a stacked ``(N, ...)`` tree with a traced
    index (the same dynamic-slice class of op the scan itself emits)."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
        stacked)


class Relay:
    """Async-aware two-slot relay over one group's stacked host tree.

    The relayed "tree" is whatever the schedule streams: the per-leaf
    pytree, or — with ``ExecutionConfig.pack_params`` — a
    ``packing.Packed`` node whose leaves are the per-dtype flat segments,
    so each ``prefetch`` issues ONE large host->HBM DMA per segment
    instead of one per param leaf.

    The schedule is issue-early / consume-late: ``warmup()`` starts the
    DMA for the first layer before the scan, and inside iteration ``i``
    the body calls ``prefetch(i)`` — a ``jax.device_put`` into device HBM
    whose *result is only consumed by the next iteration* (through the
    scan carry).  Nothing blocks inside jit: there is no
    ``jax.block_until_ready`` anywhere on this path, so XLA's
    latency-hiding scheduler is free to keep the copy for slot B in
    flight while slot A's microbatch loop computes.  On backends that
    drop memory-space transfers (CPU — see ``memories_supported``) the
    restructured scan computes bit-identical results with no-op moves.
    """

    def __init__(self, placement: Placement, stacked, *,
                 reverse: bool = False):
        self.placement = placement
        self.stacked = stacked
        self.n = jax.tree.leaves(stacked)[0].shape[0]
        self.reverse = reverse

    def warmup(self):
        """Fetch the first slot (layer 0, or N-1 for a reverse scan)."""
        return self.placement.dev(
            layer_slice(self.stacked, self.n - 1 if self.reverse else 0))

    def prefetch(self, i):
        """Issue the DMA for the layer the NEXT iteration will consume
        (l+1 forward, l-1 reverse; the final iteration re-fetches its own
        edge layer so shapes stay uniform — that copy is dropped)."""
        nxt = (jnp.maximum(i - 1, 0) if self.reverse
               else jnp.minimum(i + 1, self.n - 1))
        return self.placement.dev(layer_slice(self.stacked, nxt))


def pspecs_like(pspec_tree, target_tree):
    """Broadcast a param-shaped pspec tree onto a state tree whose leaves
    replace each param leaf with a subtree of same-shaped arrays (adam m/v)."""
    is_p = lambda x: isinstance(x, P)
    flat_p, treedef = jax.tree.flatten(pspec_tree, is_leaf=is_p)
    flat_t = treedef.flatten_up_to(target_tree)
    out = [jax.tree.map(lambda _, _p=p: _p, t) for p, t in zip(flat_p, flat_t)]
    return jax.tree.unflatten(treedef, out)


def make_placements(exec_cfg, n_groups: int, mesh=None,
                    weight_pspecs=None, opt_pspecs=None,
                    stash_pspec=None) -> EPSPlacements:
    """Single-device (tests/benchmarks) or mesh-sharded placements.

    ``weight_pspecs``/``opt_pspecs``: per-group pspec trees for one layer
    slice; required when mesh is given and streaming is on."""
    noop = noop_placement()
    if not memories_supported():
        # backend drops memory-space transfers inside jit (CPU): placement
        # becomes logical-only; the L2L schedule itself is unchanged.
        return EPSPlacements((noop,) * n_groups, (noop,) * n_groups, noop)
    if mesh is None:
        single = single_device_placement()
        w = single if exec_cfg.weight_stream else noop
        s = single if exec_cfg.offload_stash else noop
        return EPSPlacements((w,) * n_groups, (w,) * n_groups, s)
    ws = tuple(mesh_placement(mesh, weight_pspecs[g]) for g in range(n_groups)) \
        if exec_cfg.weight_stream else (noop,) * n_groups
    os_ = tuple(mesh_placement(mesh, opt_pspecs[g]) for g in range(n_groups)) \
        if exec_cfg.weight_stream else (noop,) * n_groups
    st = mesh_placement(mesh, stash_pspec if stash_pspec is not None else P()) \
        if exec_cfg.offload_stash else noop
    return EPSPlacements(ws, os_, st)
