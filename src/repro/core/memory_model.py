"""Analytic memory/time model — equations (1)-(7) of the paper,
parameterized by a ModelConfig and an ExecutionConfig.

This is the quantitative form of the paper's §3.1, used by the Table-2/4/5
benchmarks (alongside compiled memory_analysis) and by EXPERIMENTS.md's
constant-memory validation: on this CPU container the two-tier placement is
logical-only (see eps.memories_supported), so the byte accounting of what
lives in device HBM vs EPS host DRAM on the TPU target comes from here —
computed from exact layer/activation shapes, not hand-waving.
"""
from __future__ import annotations

from dataclasses import dataclass
import jax

from repro.core.relay import n_stops, segment_bounds
from repro.core.tierstore import demote_plan, ring_depth
from repro.models.common import is_spec, param_bytes
from repro.models.model import LayeredModel


def bytes_per(dtype: str) -> int:
    return {"float32": 4, "bfloat16": 2, "float16": 2}[dtype]


@dataclass
class MemoryReport:
    # bytes
    params_device: int          # weights resident in HBM
    params_host: int            # weights resident in EPS (host DRAM)
    opt_state: int              # wherever the optimizer lives (4x rule)
    activations: int            # intermediate activations at peak
    stash: int                  # layer-boundary stash (device or host)
    stash_on_host: bool
    total_device: int = 0
    total_host: int = 0
    # DMA issue counts per relay STOP per direction (l2l modes).  The
    # BYTES of eq. (2)/(3)'s transit terms are layout-independent; what
    # pack_params changes is how many host<->HBM copies carry them: the
    # per-leaf relay issues one copy per param leaf (and per optimizer
    # slot leaf in l2l_p), the packed relay one copy per dtype segment
    # (weights) / per optimizer slot (m, v).  A stop covers
    # ``layers_per_relay`` stacked layers in the SAME copies (the slice
    # just grows a leading axis), so ``relay_stops`` — total stops one
    # pass makes over the depth, sum of ceil(n_layers/G) per group, or of
    # per-segment ceilings when ``stash_every`` > 1 segments the pass —
    # is the trip-count multiplier.  Small copies are latency-bound, so
    # relay_stops * relay_copies_* — not the byte total — is the eq. (6)
    # relay-term driver the packed/grouped layouts attack.
    relay_copies_weights: int = 0
    relay_copies_opt: int = 0
    relay_stops: int = 0
    # --- constant-memory stash (stash_every = K) ------------------------
    # The stash term above is ceil(N/K)*mb*A instead of N*mb*A: only
    # every K-th layer boundary is checkpointed (stash_boundaries counts
    # them).  The backward pays for it by re-streaming each K-segment's
    # weights forward to recompute the missing boundaries:
    # recompute_layers extra layer-forwards per step (N - ceil(N/K) — the
    # flop side) issued over recompute_stops extra weight-relay stops
    # (the DMA side, ceil((len-1)/G) per segment).  Each recomputed
    # boundary is re-hosted into the STASH tier and fetched back per
    # layer (the K=1 protocol), so the recompute working set —
    # recompute_buffer = (largest segment - 1) boundaries — rides the
    # stash placement: host bytes under offload_stash (total stash-tier
    # peak ceil(N/K)+K-1 boundaries, the Chen sqrt-N curve), device bytes
    # otherwise; the device transit/activation terms never see K.  With
    # K = 1 all four reduce to the historical model (stash_boundaries =
    # N, zeros).
    stash_boundaries: int = 0
    recompute_layers: int = 0
    recompute_stops: int = 0
    recompute_buffer: int = 0
    # --- program size (scan over segments) -------------------------------
    # How many RELAY INSTANCES the lowered train step contains — distinct
    # relay scans the compiler must lower, NOT trip counts (those are
    # ``relay_stops``).  The historical K > 1 schedule unrolled one relay
    # per segment per phase: ~3·ceil(N/K) instances (fwd + recompute +
    # bwd), so trace/lower/compile time grew linearly with depth.  With
    # ``segment_scan`` every phase drives its segments through ONE outer
    # lax.scan, leaving an O(1)-in-depth count: the per-phase scans plus
    # at most one extra set for the N mod K remainder that runs outside
    # the scan.  K = 1 was never unrolled (one relay per phase).
    relay_instances: int = 0
    # --- storage tier (tiers = 3: HBM <- pinned host <- mmap/NVMe) -------
    # The cold row tail of the stacked EPS state (weights + optimizer
    # slots; gradients are transit, never demoted) that lives in the
    # on-disk SegmentStore under the host budget — planned by the SAME
    # ``tierstore.demote_plan`` the runtime executes, so the accounting
    # cannot drift from the chain.  ``disk_reads`` counts the per-step
    # stage-in segment reads: ceil(demoted / G) relay-stop chunks per
    # group, each fetching 1 weight segment + opt_slots slot segments
    # (stage-out writes the same bytes back; writes are not counted
    # here).  ``disk_read_ahead_cap`` is the prefetch ring's EFFECTIVE
    # depth: the configured prefetch_depth, shrunk by the watchdog so
    # the in-flight chunks fit the host-budget slack
    # (``tierstore.ring_depth``) — degrade, don't OOM.
    params_disk: int = 0
    opt_disk: int = 0
    total_disk: int = 0
    demoted_layers: int = 0
    disk_reads: int = 0
    disk_read_ahead_cap: int = 0
    # --- serve mode (continuous batching, estimate_serve) ---------------
    # The serve-time device residents replacing the training stash terms:
    # the paged KV pool (n_pages fixed-size pages shared by all slots —
    # the knob that decouples cache memory from max_batch * max_seq), the
    # per-slot recurrent state (SSM/conv/RWKV leaves, max_batch-major),
    # and the tick's relay DMA trip count (sum of ceil(n_layers/G) over
    # decode groups — paid ONCE per tick for ALL in-flight requests; the
    # per-request DMA cost is relay_stops_per_tick / batch).
    kv_page_bytes: int = 0
    slot_state_bytes: int = 0
    relay_stops_per_tick: int = 0
    # --- pallas relay transport (ExecutionConfig.transport) --------------
    # With transport="pallas" each relay copy runs through the
    # kernels/relay_copy double-buffered DMA pipeline: at most TWO chunks
    # of the slot are in flight at once, so the kernel's working set
    # beyond the (already-counted) destination slot is the 2-chunk DMA
    # window — 2 * slot_bytes / chunks_per_slot (one chunk per stacked
    # row for G >= 2, two half-row chunks for single-layer slots).  Zero
    # under the historical "xla" transport.
    transport_buffer: int = 0

    def finalize(self):
        self.total_device = (self.params_device + self.activations
                             + self.kv_page_bytes + self.slot_state_bytes
                             + self.transport_buffer
                             + (0 if self.stash_on_host
                                else self.stash + self.recompute_buffer))
        self.total_host = (self.params_host + self.opt_state
                           + ((self.stash + self.recompute_buffer)
                              if self.stash_on_host else 0))
        self.total_disk = self.params_disk + self.opt_disk
        return self


def _layer_bytes(model: LayeredModel, dtype_bytes: int):
    """(max single-layer bytes, total stacked-layer bytes)."""
    per_layer = [param_bytes(g.spec, dtype_bytes) for g in model.groups]
    totals = [p * g.n_layers for p, g in zip(per_layer, model.groups)]
    return max(per_layer), sum(totals)


def _slot_bytes(model: LayeredModel, dtype_bytes: int, group: int) -> int:
    """Largest relay-slot bytes: a slot holds min(G, n_layers) stacked
    layers (G may exceed a shallow group's depth — the slot is then just
    that group's whole stack), so the peak is over groups of that."""
    return max(param_bytes(g.spec, dtype_bytes) * min(group, g.n_layers)
               for g in model.groups)


def estimate(model: LayeredModel, *, batch: int, seq: int,
             n_microbatches: int = 1, mode: str = "l2l",
             offload_stash: bool = False, opt_slots: int = 2,
             act_dtype_bytes: int = 2, param_dtype_bytes: int = 4,
             prefetch_depth: int = 0,
             pack_params: bool = False,
             layers_per_relay: int = 1,
             stash_every: int = 1,
             segment_scan: bool = True,
             tiers: int = 2,
             host_budget: int = 0,
             model_shards: int = 1,
             transport: str = "xla") -> MemoryReport:
    """Modes:
      baseline      eq. (1): everything device-resident
      baseline_remat eq. (1) with the N*L*mb*X term reduced to boundaries
      l2l           eq. (2): one layer (+1 transit buffer) on device,
                    stash of N*mb*A boundaries on device
      l2l_p         eq. (3)/(4): + weight/grad transit buffers; stash to
                    host when offload_stash (the constant-memory variant)

    ``prefetch_depth`` (k) and ``layers_per_relay`` (G) — l2l modes only —
    make the paper's "the executing layer(s)'s footprint" plural explicit:
    the relay ring keeps G·(1 + k) full layer slots in HBM (one G-layer
    compute slot + k in-flight DMA slots), so the device weight-transit
    footprint is G·(1 + k) × eq. (2)/(3)'s — still O(1) in depth N.  A
    slot never holds more than a group's whole stack, so G is capped at
    the deepest group's depth in the footprint.  G also divides the
    relay trip count: one pass makes ``relay_stops`` = sum over groups
    of ceil(n_layers / G) stops instead of N.

    ``stash_every`` (K, l2l modes only) is the constant-memory stash:
    only every K-th layer boundary is checkpointed, so the stash term
    drops from N*mb*A to ceil(N/K)*mb*A — sublinear in depth wherever it
    lives (device or, with ``offload_stash``, EPS host).  The price is
    accounted in ``recompute_layers`` (N - ceil(N/K) extra layer-forwards
    per step) and ``recompute_stops`` (the extra forward weight-relay
    stops the backward issues to recompute each segment's missing
    boundaries), and in ``recompute_buffer``: the (largest segment - 1)
    recomputed boundaries the STASH TIER transiently holds while a
    segment's backward runs (host under ``offload_stash``, device
    otherwise — without offload the stash-tier peak is the Chen
    ceil(N/K) + K - 1 sqrt-N curve).  Because every relay then runs over
    one K-segment, the device relay slot is capped at min(G, K, depth)
    layers — K < G shrinks the weight-transit footprint too.  K = 1
    reproduces today's model byte-for-byte.

    ``segment_scan`` (l2l modes, K > 1 only) changes no byte term — it is
    purely a PROGRAM-SIZE knob, reported in ``relay_instances``: the
    distinct relay scans the lowered train step contains.  True (the
    runtime default) drives all of a phase's segments through one outer
    lax.scan — O(1) instances in depth; False re-emits the historical
    unrolled per-segment program — ~3·ceil(N/K) instances, the
    depth-proportional compile-time blowup ``benchmarks/fig_compile.py``
    measures.

    ``pack_params`` (l2l modes only) does NOT change any byte term — the
    transit buffers of eq. (2)/(3) hold the same elements whether they
    arrive as one flat segment or N leaf arrays.  What it changes is the
    reported ``relay_copies_*`` DMA issue counts: per-leaf relay pays one
    host<->HBM copy per param leaf per stop per direction (plus one per
    optimizer-slot leaf in l2l_p), the packed relay one copy per dtype
    segment (weights) and one per optimizer slot (m, v) — the
    latency-bound small-transfer term eq. (6) hides inside its bandwidth
    model.

    ``tiers``/``host_budget`` (l2l modes only) account the storage tier:
    with ``tiers = 3`` the coldest stacked rows of the EPS state (weights
    + opt slots; grads are transit) demote to the on-disk SegmentStore —
    planned by the SAME ``tierstore.demote_plan`` the runtime executes
    (``host_budget = 0`` demotes everything: fully streamed).  Demoted
    bytes move from ``params_host``/``opt_state`` into
    ``params_disk``/``opt_disk``; ``disk_reads`` counts the per-step
    stage-in segment reads and ``disk_read_ahead_cap`` the
    watchdog-shrunk effective prefetch depth (``tierstore.ring_depth``).

    ``transport`` (l2l modes only) accounts the pallas copy kernel's
    double-buffer window: ``"pallas"`` adds ``transport_buffer`` = two
    in-flight DMA chunks of the relay slot (the semaphore-paced pipeline
    of ``kernels/relay_copy`` — one chunk per stacked slot row when the
    slot is grouped, two half-row chunks for a single-layer slot); the
    historical ``"xla"`` transport adds nothing.

    ``model_shards`` divides the per-device/per-host BYTE terms (relay
    slot, host-resident stack, opt state, disk tier) for a program model-
    sharded over that many devices — the relay slot a device fetches and
    the stack a host holds are 1/shards of the full layer.  Activation /
    stash terms are NOT divided (batch-sharding is a separate axis):
    the estimate stays conservative.  ``host_budget`` is then PER HOST.
    """
    cfg = model.cfg
    d = cfg.d_model
    L_max, L_total = _layer_bytes(model, param_dtype_bytes)
    n_layers = sum(g.n_layers for g in model.groups)
    # A: boundary activation bytes per sample; X: intra-layer activation
    # bytes per sample (attention scores excluded — flash/chunked streaming)
    A = seq * d * act_dtype_bytes
    ff = max(cfg.d_ff, cfg.d_ff_expert * max(cfg.experts_per_token, 1)
             if cfg.n_experts else cfg.d_ff)
    X = seq * (2 * d + 2 * ff) * act_dtype_bytes
    ub = max(1, batch // max(n_microbatches, 1))

    if mode.startswith("baseline"):
        act = batch * X * (1 if mode.endswith("remat") else n_layers)
        stash = n_layers * batch * A if mode.endswith("remat") else 0
        return MemoryReport(
            params_device=L_total,
            params_host=0,
            opt_state=(1 + opt_slots) * L_total,   # grads + adam m,v
            activations=act,
            stash=stash, stash_on_host=False).finalize()

    G = max(1, layers_per_relay)
    K = max(1, stash_every)
    transit = 2 if mode == "l2l" else 4            # eq.(2) vs eq.(3)
    transit *= 1 + prefetch_depth                  # ring of G-layer slots
    # a slot holds min(G, group depth) layers — G beyond the deepest
    # group adds no residency (the remainder-only pass).  With
    # stash_every = K > 1 every relay runs over one K-segment, so the
    # slot is further capped at the segment length: min(G, K, depth).
    slot = _slot_bytes(model, param_dtype_bytes, min(G, K) if K > 1 else G)
    # DMA issues per relay stop per direction (largest group): the
    # per-leaf relay pays one copy per leaf; the packed relay one per
    # dtype segment (a single param_dtype here) / per optimizer slot.
    # Grouping keeps these counts (the slice grows a leading G axis) but
    # divides the trip count: relay_stops = sum ceil(n_layers / G)
    # (relay.n_stops — the executor's own arithmetic).
    n_leaves = max(len(jax.tree.leaves(g.spec, is_leaf=is_spec))
                   for g in model.groups)
    copies_w = 1 if pack_params else n_leaves
    copies_o = ((opt_slots if pack_params else n_leaves * opt_slots)
                if mode == "l2l_p" else 0)
    # constant-memory stash: ceil(N/K) checkpointed boundaries per group;
    # the backward re-streams each segment's first len-1 layers forward
    # to recompute the in-between boundaries (extra stops + layer flops)
    segs = [segment_bounds(g.n_layers, K) for g in model.groups]
    if K == 1:
        stops = sum(n_stops(g.n_layers, G) for g in model.groups)
    else:
        # K > 1 segments every forward/backward pass: one relay per
        # segment, so a pass issues ceil(len/G) stops per segment —
        # more than ceil(N/G) when K is not a multiple of G
        stops = sum(n_stops(s1 - s0, G)
                    for gsegs in segs for s0, s1 in gsegs)
    n_ckpt = sum(len(s) for s in segs)
    rec_layers = n_layers - n_ckpt
    rec_stops = sum(n_stops(s1 - s0 - 1, G)
                    for gsegs in segs for s0, s1 in gsegs if s1 - s0 > 1)
    # recompute working set: while one segment's backward runs, the
    # stash tier additionally holds its seg_len - 1 recomputed
    # boundaries (the entry is one of the persistent checkpoints)
    rec_buffer = (max(max(s1 - s0 for s0, s1 in gsegs)
                      for gsegs in segs) - 1) * batch * A if K > 1 else 0
    # program size: distinct relay instances the lowered step contains.
    # K = 1 was never segmented: one fwd + one bwd relay (+ trailing
    # update relay under the non-eager optimizer) per group.
    upd = 1 if mode == "l2l" else 0
    if K == 1:
        instances = len(model.groups) * (2 + upd)
    elif not segment_scan:
        # unrolled: one fwd + one bwd relay per segment, one recompute
        # relay per multi-layer segment — grows with ceil(N/K)
        n_rec = sum(1 for gsegs in segs for s0, s1 in gsegs if s1 - s0 > 1)
        instances = (sum(2 * len(gsegs) for gsegs in segs) + n_rec
                     + len(model.groups) * upd)
    else:
        # one outer scan per phase (fwd relay; rec + bwd relays share the
        # reverse scan body) plus the N mod K remainder's relays outside
        instances = 0
        for g in model.groups:
            R = g.n_layers % K
            instances += 3 + upd
            if R:
                instances += 2 + (1 if R > 1 else 0)
    # --- model sharding + storage tier -----------------------------------
    shards = max(1, int(model_shards))
    shard = lambda b: -(-b // shards)              # ceil: stay conservative
    per_layer_w = [shard(param_bytes(g.spec, param_dtype_bytes))
                   for g in model.groups]
    # demotable stacked state per layer row: weights + the opt slots that
    # live alongside them in the store (grads are transit, never stored)
    per_layer_state = [p * (1 + opt_slots) for p in per_layer_w]
    n_list = [g.n_layers for g in model.groups]
    L_total_s = sum(p * n for p, n in zip(per_layer_w, n_list))
    params_host = L_total_s
    opt_host = (1 + opt_slots) * L_total_s         # EPS-resident
    params_disk = opt_disk = demoted = reads = cap = 0
    if tiers >= 3:
        hot = demote_plan(per_layer_state, n_list, host_budget)
        dem = [n - h for h, n in zip(hot, n_list)]
        demoted = sum(dem)
        params_disk = sum(d_ * p for d_, p in zip(dem, per_layer_w))
        opt_disk = sum(d_ * p * opt_slots
                       for d_, p in zip(dem, per_layer_w))
        params_host -= params_disk
        opt_host -= opt_disk
        # stage-in reads: ceil(demoted / G) chunks per group, each
        # fetching 1 weight segment + opt_slots slot segments
        reads = sum(n_stops(d_, G) * (1 + opt_slots) for d_ in dem if d_)
        if demoted:
            chunk = G * max(s for d_, s in zip(dem, per_layer_state)
                            if d_)
            resident = sum(h * s for h, s in zip(hot, per_layer_state))
            cap = ring_depth(prefetch_depth, chunk,
                             max(0, host_budget - resident),
                             bounded=host_budget > 0)
    # pallas transport: the copy kernel keeps two DMA chunks of a slot in
    # flight (one chunk per stacked row of a grouped slot, two half-row
    # chunks for a single-layer slot)
    slot_rows = min(G, K) if K > 1 else G
    chunks = slot_rows if slot_rows >= 2 else 2
    trans_buf = (-(-2 * shard(slot) // chunks)
                 if transport == "pallas" else 0)
    return MemoryReport(
        params_device=transit * shard(slot),
        params_host=params_host,
        opt_state=opt_host,
        activations=ub * X,                        # recompute working set
        stash=n_ckpt * batch * A,
        stash_on_host=offload_stash,
        relay_copies_weights=copies_w,
        relay_copies_opt=copies_o,
        relay_stops=stops,
        stash_boundaries=n_ckpt,
        recompute_layers=rec_layers,
        recompute_stops=rec_stops,
        recompute_buffer=rec_buffer,
        relay_instances=instances,
        params_disk=params_disk,
        opt_disk=opt_disk,
        demoted_layers=demoted,
        disk_reads=reads,
        disk_read_ahead_cap=cap,
        transport_buffer=trans_buf).finalize()


def estimate_serve(model: LayeredModel, *, max_batch: int, page_size: int,
                   n_pages: int, max_seq: int, prefill_chunk: int = 1,
                   weight_stream: bool = True, prefetch_depth: int = 0,
                   pack_params: bool = False, layers_per_relay: int = 1,
                   act_dtype_bytes: int = 2, cache_dtype_bytes: int = 2,
                   param_dtype_bytes: int = 4,
                   transport: str = "xla") -> MemoryReport:
    """Serve-mode byte split for the continuous-batching engine
    (``repro.serve``): no optimizer / stash terms; instead the device
    holds the paged KV pool, the per-slot recurrent state and — with
    ``weight_stream`` — the G·(1 + prefetch) relay slots of eq. (2)'s
    weight transit (the whole stack stays EPS-resident).  The per-tick
    relay DMA trip count lands in ``relay_stops_per_tick``: layer-major
    continuous batching pays it once per tick for every in-flight
    request, so its per-request share shrinks as concurrency grows — the
    scaling ``benchmarks/fig_serve.py`` measures.
    """
    from repro.serve.paged_kv import pool_bytes
    cfg = model.cfg
    d = cfg.d_model
    L_max, L_total = _layer_bytes(model, param_dtype_bytes)
    G = max(1, layers_per_relay)
    kv, slot_state, _ = pool_bytes(
        model, max_batch=max_batch, page_size=page_size, n_pages=n_pages,
        max_seq=max_seq, cache_dtype_bytes=cache_dtype_bytes)
    ff = max(cfg.d_ff, cfg.d_ff_expert * max(cfg.experts_per_token, 1)
             if cfg.n_experts else cfg.d_ff)
    # the tick's live activations: max_batch rows x prefill_chunk query
    # positions through one layer's working set
    act = max_batch * prefill_chunk * (2 * d + 2 * ff) * act_dtype_bytes
    if weight_stream:
        slot = _slot_bytes(model, param_dtype_bytes, G)
        params_device = (1 + prefetch_depth) * slot
        params_host = L_total
    else:
        params_device, params_host, slot = L_total, 0, 0
    trans_buf = (-(-2 * slot // (G if G >= 2 else 2))
                 if transport == "pallas" and weight_stream else 0)
    n_leaves = max(len(jax.tree.leaves(g.spec, is_leaf=is_spec))
                   for g in model.groups)
    stops = sum(n_stops(g.n_layers, G) for g in model.decode_groups())
    return MemoryReport(
        params_device=params_device,
        params_host=params_host,
        opt_state=0,
        activations=act,
        stash=0, stash_on_host=False,
        relay_copies_weights=1 if pack_params else n_leaves,
        relay_stops=stops,
        kv_page_bytes=kv,
        slot_state_bytes=slot_state,
        relay_stops_per_tick=stops if weight_stream else 0,
        transport_buffer=trans_buf).finalize()


# ---------------------------------------------------------------------------
# Time model — equations (5)-(7)
# ---------------------------------------------------------------------------
@dataclass
class TimeModel:
    n_layers: int
    layer_bytes: float          # L in bytes
    f_t: float                  # forward time per microbatch (s)
    b_t: float                  # backward time per microbatch (s)
    o_t: float                  # optimizer time on device (s)
    o_tc: float                 # optimizer time on EPS/CPU (s)
    hb: float                   # host->device bandwidth bytes/s
    u: int                      # microbatches per minibatch

    def baseline(self) -> float:                       # eq. (5)
        return self.n_layers * self.u * (self.f_t + self.b_t) + self.o_t

    def l2l(self) -> float:                            # eq. (6)
        relay = self.n_layers * 2 * self.layer_bytes / self.hb
        compute = self.n_layers * self.u * (2 * self.f_t + self.b_t)
        return relay + compute + self.o_tc

    def l2l_p(self) -> float:                          # eq. (7)
        compute = self.n_layers * self.u * (2 * self.f_t + self.b_t)
        opt_exposed = max(0.0, self.o_tc
                          - self.n_layers * self.u * self.b_t)
        relay_exposed = max(0.0, self.n_layers * (
            self.layer_bytes / self.hb - self.u * self.f_t))
        return compute + opt_exposed + relay_exposed


def paper_worked_example() -> TimeModel:
    """§3.1.2: BERT-Large, V100 @30 TFLOPs effective, mb=64, u=16 (ub=4),
    fwd 12 GFLOP/layer/sample, bwd 24, optimizer 100 GFLOP, EPS 300 GFLOPs,
    PCIe 16 GB/s, L = 350M params / 24 layers * 4B."""
    tf = 30e12
    return TimeModel(
        n_layers=24,
        layer_bytes=350e6 / 24 * 4,
        f_t=12e9 * 4 / tf,
        b_t=24e9 * 4 / tf,
        o_t=100e9 / tf,
        o_tc=100e9 / 300e9,
        hb=16e9,
        u=16)


def for_config(model: LayeredModel, *, batch: int, seq: int, u: int,
               flops_per_s: float = 197e12, eps_flops: float = 2e12,
               hb: float = 100e9) -> TimeModel:
    """Time model for an assigned arch on the TPU v5e target (hb = host DMA
    estimate, eps_flops = host optimizer throughput)."""
    cfg = model.cfg
    n_active = cfg.param_count(active_only=True)
    L_max, L_total = _layer_bytes(model, 4)
    n_layers = sum(g.n_layers for g in model.groups)
    ub = max(1, batch // u)
    tokens = ub * seq
    f = 2 * n_active / n_layers * tokens / flops_per_s
    return TimeModel(
        n_layers=n_layers, layer_bytes=L_max,
        f_t=f, b_t=2 * f,
        o_t=10 * cfg.param_count() / flops_per_s,
        o_tc=10 * cfg.param_count() / eps_flops,
        hb=hb, u=u)
