"""Unified relay executor — the ONE place that issues layer-relay DMAs.

Every layer-major scan in the repo (L2L train forward, reverse backward,
Alg-3 trailing update, prefill, serve-decode) is the same composition:

* one or more **streams** — stacked ``(N, ...)`` host-resident trees
  (weights, shipped gradients, optimizer slots; plain pytrees or
  ``packing.Packed`` flat buffers) relayed stop-by-stop into device HBM,
* a **prefetch ring** — ``prefetch_depth + 1`` HBM slots, generalizing
  the old two-slot double buffer: the DMA for stop ``i + k`` is issued at
  the top of stop ``i``'s body, so up to ``k`` transfers are in flight
  while one slot computes (``prefetch_depth = 0`` keeps the historical
  fetch-inside-the-iteration schedule),
* **layer groups** — ``layers_per_relay = G`` relays G stacked layers per
  stop: ONE dynamic-slice + ``device_put`` per stream covers G layers
  (one copy per leaf, or one per dtype segment when packed), and the body
  runs per layer over the G-layer sub-stack inside the stop.  The paper's
  §3.1 "the executing **layer(s)**" is plural exactly here: the device
  footprint is G·(1 + prefetch_depth) layer slots, traded against
  ceil(N/G) relay stops instead of N.

``relay_scan`` owns all of that; consumers only write a per-layer body.
The composition is a pure SCHEDULE/layout change: for any (G,
prefetch_depth, pack_params) the math is bit-identical to the G=1,
depth-0, unpacked scan (asserted by tests/test_relay.py).

Mechanics worth knowing:

* The main scan runs over the ``N // G`` full stops; a depth not
  divisible by G leaves a short remainder stop of ``N mod G`` layers that
  is executed outside the scan (after it in a forward pass, before it in
  a reverse pass, preserving layer order) with its own — unoverlapped —
  fetch.  With G = 1 there is never a remainder and the emitted program
  is exactly the historical per-layer scan.
* Nothing blocks inside jit: fetches are plain ``jax.device_put`` whose
  results are consumed one-or-more iterations later through the scan
  carry, so XLA's latency-hiding scheduler keeps ring copies in flight
  while the current slot computes.  On backends that drop memory-space
  transfers (CPU — see ``eps.memories_supported``) the restructured scan
  computes identical results with no-op moves.
* ``ys`` keep layer order: a reverse scan stacks a layer's outputs at its
  forward index (matching ``lax.scan(reverse=True)`` semantics), and
  grouped stops stack their G per-layer outputs in forward order before
  the scan stacks the stops.
* ``active=(lo, hi)`` + ``idle_body`` gate each stop with a traced layer
  window: stops outside it run the idle body (pass activations through,
  re-ship slots so inactive rows stay bit-frozen).  This is how
  ``segment_scan`` runs a traced segment window inside one scan and how
  ``dynamic_depth`` masks layers past the runtime depth.  ``active=None``
  keeps the emitted program byte-identical to the historical one.
* ``segment_scan`` (below) wraps ``relay_scan`` callers that used to
  unroll one relay per K-segment: ONE outer ``lax.scan`` over the
  ``N // K`` full segments with a traced segment start drives dynamic
  slices of the stacked streams; the ``N mod K`` remainder is a static
  epilogue outside the scan.  The compiled program becomes O(1) in
  depth while staying bit-identical to the unrolled form.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.eps import Placement

# kernels.relay_copy, imported once per process.  The pallas transport's
# ``fetch`` runs once per relay stop per trace — a module-level lazy
# import keeps Python's import machinery out of every fetch.
_RELAY_COPY = None


def _relay_copy():
    global _RELAY_COPY
    if _RELAY_COPY is None:
        from repro.kernels import relay_copy
        _RELAY_COPY = relay_copy
    return _RELAY_COPY


class Stream(NamedTuple):
    """One stacked host-resident tree relayed by a ``relay_scan``."""
    placement: Placement
    stacked: Any                 # (N, ...) tree (possibly packing.Packed)


def layer_slice(stacked, i):
    """Slice layer ``i`` out of a stacked ``(N, ...)`` tree with a traced
    index (the same dynamic-slice class of op the scan itself emits)."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
        stacked)


def group_slice(stacked, start, size: int):
    """Slice ``size`` consecutive layers starting at ``start`` (traced or
    static) — the G-layer relay slot, leading axis kept."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, start, size, axis=0),
        stacked)


def _index(tree, j: int):
    return jax.tree.map(lambda a: a[j], tree)


def _stack(ys_list):
    return jax.tree.map(lambda *ls: jnp.stack(ls), *ys_list)


def n_stops(n_layers: int, group: int) -> int:
    """Relay stops one pass makes over ``n_layers`` (ceil division)."""
    g = max(1, group)
    return -(-n_layers // g)


def stop_bounds(n_layers: int, group: int, start: int = 0) -> tuple:
    """Static ``(lo, hi)`` layer ranges of each relay stop over
    ``n_layers`` layers beginning at ``start`` — G full stops plus the
    short remainder, ``n_stops(n_layers, group)`` entries.  This is the
    chunk schedule the storage tier's disk prefetch ring shares with the
    in-jit relay (``core.tierstore``): one contiguous pread per stop."""
    g = max(1, group)
    return tuple((start + lo, start + min(lo + g, n_layers))
                 for lo in range(0, n_layers, g))


def segment_bounds(n_layers: int, every: int) -> tuple:
    """Static ``(start, stop)`` layer ranges of the stash segments when
    only every ``every``-th boundary is checkpointed
    (``ExecutionConfig.stash_every``): boundaries sit at layer indices
    = 0 (mod K), so segments are ``[0, K), [K, 2K), ...`` with a short
    remainder segment at the end when K does not divide N.  One entry per
    stored boundary — ``len(segment_bounds(n, K)) == ceil(n / K)``."""
    k = max(1, int(every))
    return tuple((s, min(s + k, n_layers))
                 for s in range(0, n_layers, k))


def relay_scan(body: Callable, init, streams: Sequence[Stream], *,
               xs=None, reverse: bool = False, group: int = 1,
               prefetch: int = 0, unroll=False, transport: str = "xla",
               active: Optional[tuple] = None,
               idle_body: Optional[Callable] = None):
    """Run ``body`` once per layer under the unified relay schedule.

    ``body(carry, slots, x) -> (carry, ys)`` is PER LAYER:

    * ``slots`` — tuple of HBM-resident single-layer trees, one per
      stream (already fetched; no leading axis),
    * ``x`` — the layer's slice of ``xs`` (None when ``xs`` is None),
    * ``ys`` — per-layer outputs, stacked to ``(N, ...)`` in layer order
      (or None).

    Returns ``(carry, ys)`` like ``lax.scan``; ``reverse=True`` walks
    layers N-1..0 but still stacks ``ys`` in forward order.

    ``transport`` picks the slot mover: ``"xla"`` (historical) slices +
    ``device_put``s and lets XLA schedule the copies; ``"pallas"`` moves
    every slot through ``kernels.relay_copy``'s double-buffered
    ``make_async_copy`` pipeline, so the ring's overlap is enforced by
    DMA semaphores inside the emitted kernel.  Pure transport — results
    are bit-identical (tests/test_transport.py).

    ``active`` makes the trip count a RUNTIME value: a traced half-open
    ``(lo, hi)`` window of local layer indices.  Rows inside the window
    run ``body``; rows outside run ``idle_body`` (same signature, same
    output avals — typically the carry passed through untouched and the
    incoming slots re-shipped) under a per-layer ``lax.cond``, so ONE
    compiled program serves every window value — the mechanism behind
    ``ExecutionConfig.dynamic_depth``.  ``active=None`` (the default)
    emits the historical ungated program unchanged.
    """
    streams = tuple(streams)
    assert streams, "relay_scan needs at least one stream"
    n = jax.tree.leaves(streams[0].stacked)[0].shape[0]
    G = max(1, int(group))
    K = max(0, int(prefetch))
    S = n // G                    # full stops covered by the main scan
    R = n - S * G                 # remainder stop (0 when G divides N)

    if active is None:
        def call_body(carry, slots, x, idx):
            return body(carry, slots, x)
    else:
        assert idle_body is not None, \
            "relay_scan(active=...) needs an idle_body with matching " \
            "output structure"
        lo, hi = active

        def call_body(carry, slots, x, idx):
            on = jnp.logical_and(idx >= lo, idx < hi)
            return jax.lax.cond(on,
                                lambda c: body(c, slots, x),
                                lambda c: idle_body(c, slots, x),
                                carry)

    def fetch(start, size: int):
        """ONE host->HBM copy per stream (per leaf / dtype segment) for a
        ``size``-layer slot — the only DMA issue site in the repo."""
        if transport == "pallas":
            # the copy IS the transfer: rows [start, start+size) of every
            # leaf/segment move through the double-buffered DMA kernel
            # (squeezed to the single-layer layout when G == 1, matching
            # layer_slice below)
            relay_copy = _relay_copy()
            return tuple(
                relay_copy.fetch_slot(s.stacked, start, size,
                                      squeeze=(G == 1))
                for s in streams)
        if G == 1:
            return tuple(s.placement.dev(layer_slice(s.stacked, start))
                         for s in streams)
        return tuple(
            (s.placement.dev_grouped or s.placement.dev)(
                group_slice(s.stacked, start, size))
            for s in streams)

    def run_stop(carry, slots, start, size: int):
        """Per-layer loop over one fetched G-layer slot (static trips)."""
        x_stop = None if xs is None else group_slice(xs, start, size)
        ys = [None] * size
        order = range(size - 1, -1, -1) if reverse else range(size)
        for j in order:
            slot_j = tuple(_index(s, j) for s in slots)
            x_j = None if x_stop is None else _index(x_stop, j)
            carry, ys[j] = call_body(carry, slot_j, x_j, start + j)
        if all(y is None for y in ys):
            return carry, None
        return carry, _stack(ys)

    def run_remainder(carry):
        return run_stop(carry, fetch(S * G, R), S * G, R)

    ys_rem = None
    if reverse and R:
        # reverse execution visits the trailing short stop first
        carry, ys_rem = run_remainder(init)
        init = carry

    ys_main = None
    if S > 0:
        idxs = jnp.arange(S)
        if K == 0 and G == 1 and transport == "xla" and active is None:
            # historical per-layer scan, reproduced exactly: streams and
            # xs ride the scan's native xs slicing; the fetch happens at
            # the top of the consuming iteration
            def stop_body(carry, scan_x):
                host_slots, x = scan_x
                slots = tuple(s.placement.dev(t)
                              for s, t in zip(streams, host_slots))
                return body(carry, slots, x)

            carry, ys_main = jax.lax.scan(
                stop_body, init, (tuple(s.stacked for s in streams), xs),
                reverse=reverse, unroll=unroll)
        elif K == 0 and G == 1:
            # pallas transport can't ride the scan's native xs slicing —
            # the DMA kernel must issue the copy itself, so the stop
            # index drives an explicit per-layer fetch (same schedule:
            # fetch at the top of the consuming iteration).  A gated
            # (``active``) xla relay routes here too: the cond needs the
            # layer index the native-xs path never sees.
            def stop_body(carry, scan_x):
                i, x = scan_x
                return call_body(carry, fetch(i, 1), x, i)

            carry, ys_main = jax.lax.scan(stop_body, init, (idxs, xs),
                                          reverse=reverse, unroll=unroll)
        elif K == 0:
            def stop_body(carry, i):
                return run_stop(carry, fetch(i * G, G), i * G, G)

            carry, ys_main = jax.lax.scan(stop_body, init, idxs,
                                          reverse=reverse, unroll=unroll)
        else:
            # K-deep ring: the carry holds the slots for stops i..i+K-1
            # (i-K+1..i reversed); the body consumes ring[0] and issues
            # the DMA for stop i+K (i-K) before the per-layer loop, so up
            # to K transfers overlap compute.  Edge iterations re-fetch a
            # clamped edge stop; those copies are dropped.
            def nxt(i):
                return (jnp.maximum(i - K, 0) if reverse
                        else jnp.minimum(i + K, S - 1))

            if G == 1:
                # per-layer xs still ride the scan's native slicing
                def stop_body(carry_ring, scan_x):
                    i, x = scan_x
                    carry, ring = carry_ring
                    fetched = fetch(nxt(i) * G, G)
                    carry, ys = call_body(carry, ring[0], x, i)
                    return (carry, ring[1:] + (fetched,)), ys

                scan_xs = (idxs, xs)
            else:
                def stop_body(carry_ring, i):
                    carry, ring = carry_ring
                    fetched = fetch(nxt(i) * G, G)
                    carry, ys = run_stop(carry, ring[0], i * G, G)
                    return (carry, ring[1:] + (fetched,)), ys

                scan_xs = idxs

            first, step = (S - 1, -1) if reverse else (0, 1)
            ring0 = tuple(
                fetch(min(max(first + step * d, 0), S - 1) * G, G)
                for d in range(K))
            (carry, _), ys_main = jax.lax.scan(
                stop_body, (init, ring0), scan_xs, reverse=reverse,
                unroll=unroll)
    else:
        carry = init

    if not reverse and R:
        carry, ys_rem = run_remainder(carry)

    return carry, _combine_ys(ys_main, ys_rem, S, G)


def _combine_ys(ys_main, ys_rem, n_full_stops: int, group: int):
    """(S, G, ...) main-scan ys + (R, ...) remainder ys -> (N, ...)."""
    if group == 1 or ys_main is None:
        return ys_main if ys_rem is None else ys_rem
    flat = jax.tree.map(
        lambda a: a.reshape((n_full_stops * group,) + a.shape[2:]), ys_main)
    if ys_rem is None:
        return flat
    return jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                        flat, ys_rem)


# ===========================================================================
# Segment-major driver: ONE scan over the stash segments
# ===========================================================================
def segment_scan(seg_body: Callable, init, *, n_layers: int, every: int,
                 xs=None, xs_rem=None, reverse: bool = False,
                 n_active=None, unroll=False):
    """Drive ``seg_body`` over the ``segment_bounds(n_layers, every)``
    stash segments through ONE ``lax.scan`` — the program stops growing
    with depth.

    The historical constant-memory stash (``ExecutionConfig.stash_every``
    = K > 1) unrolled one relay per segment per phase: ~3·ceil(N/K) scan
    instances in the lowered train step, so trace/compile time and
    program size grew linearly with depth.  Here the ``N // K`` full
    segments ride one outer scan whose carry walks the segment schedule
    (the segment start is ``si * K``, a traced index feeding
    ``group_slice``'s dynamic slices), and the short remainder segment
    (``N mod K`` layers — a different trip count, hence a different
    program) runs OUTSIDE the scan: after it on a forward walk, before
    it on a reverse walk, exactly where the unrolled schedule placed it.

    ``seg_body(carry, start, size, x_seg, window) -> (carry, ys)``:

    * ``start`` — traced index of the segment's first layer,
    * ``size``  — STATIC segment length (K, or the remainder),
    * ``x_seg`` — this segment's slice of ``xs`` (scanned segments) /
      ``xs_rem`` (the remainder); None when not provided,
    * ``window`` — None, or a traced ``(lo, hi)`` local active-row
      window (``n_active`` mode) to forward to ``relay_scan(active=...)``.

    ``n_active`` (a traced layer count) gates segments for runtime-
    dynamic depth: every segment gets ``window = (0, clip(n_active -
    start, 0, K))``.  Dynamic bounds cannot split a remainder out of the
    scan (the split point would be value-dependent), so ``n_active``
    requires ``every`` to divide ``n_layers`` — the CAPACITY depth;
    the runtime depth may land anywhere inside a segment.

    Returns ``(carry, ys_scan, ys_rem)``: the scanned segments' stacked
    ys (leading axis = number of full segments) and the remainder's ys
    (None when there is no remainder).  Per-layer ys flatten back to
    layer order with ``flatten_segments``.
    """
    n = int(n_layers)
    K = min(max(1, int(every)), n)
    S = n // K                    # full segments covered by the scan
    R = n - S * K                 # short remainder segment (< K layers)
    if n_active is not None:
        assert R == 0, \
            f"dynamic depth needs stash_every ({K}) to divide the " \
            f"capacity depth ({n})"

    def window(si):
        if n_active is None:
            return None
        return (jnp.int32(0), jnp.clip(n_active - si * K, 0, K))

    def scan_body(carry, scan_x):
        si, x_seg = scan_x
        return seg_body(carry, si * K, K, x_seg, window(si))

    carry, ys_rem = init, None
    if reverse and R:
        carry, ys_rem = seg_body(carry, S * K, R, xs_rem, None)
    ys_scan = None
    if S:
        carry, ys_scan = jax.lax.scan(
            scan_body, carry, (jnp.arange(S), xs), reverse=reverse,
            unroll=unroll)
    if not reverse and R:
        carry, ys_rem = seg_body(carry, S * K, R, xs_rem, None)
    return carry, ys_scan, ys_rem


def flatten_segments(ys_scan, ys_rem):
    """(S, K, ...) segment-scanned per-layer ys + (R, ...) remainder ys
    -> (N, ...) in layer order (either side may be None)."""
    if ys_scan is None:
        return ys_rem
    flat = jax.tree.map(
        lambda a: a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:]),
        ys_scan)
    if ys_rem is None:
        return flat
    return jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                        flat, ys_rem)
