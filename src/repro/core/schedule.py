"""Execution schedule configuration.

Maps the paper's knobs onto one frozen config (consumed through the
``repro.engine`` facade — the registered engines pin ``eager_optimizer``):

* Algorithm 1  -> engine "baseline" with ``n_microbatches=1``
* Algorithm 2  -> engine "baseline" with ``n_microbatches=u``
* Algorithm 3  -> engine "l2l"   (trailing optimizer)
* Algorithm 4  -> engine "l2l-p" (per-layer optimize inside the reverse
  scan, per-layer eager gradient reduction via the sharded scan body)

``offload_stash`` is eq. (4): boundary activations live in pinned_host
between forward and backward.  ``stash_every`` (K) is the constant-memory
refinement of that stash: only every K-th layer boundary is stored
(ceil(N/K) instead of N) and the reverse relay recomputes the in-between
boundaries by re-streaming each K-segment's weights forward before its
backward — the stash stops growing with depth at the cost of one extra
layer-forward for K-1 of every K layers.  ``weight_stream`` is the EPS
proper: the
stacked layer params (and optimizer state) are resident in pinned_host
and relayed to device memory by the unified relay executor
(``repro.core.relay``).  Three orthogonal knobs shape that relay:

* ``layers_per_relay`` (G) — layers moved per relay stop: one DMA (or one
  packed segment copy) covers G stacked layers, and the microbatch loop
  runs the G-layer sub-stack before the next stop;
* ``prefetch_depth`` (k) — in-flight HBM slots beyond the executing one:
  a ring of k + 1 slots whose host->device copies are issued k stops
  ahead of their consumer (0 = historical fetch-in-iteration);
* ``pack_params`` — slot transport layout: per-dtype flat segments
  (one copy per segment) vs per-leaf pytrees (one copy per leaf).

The device weight footprint is G·(1 + k) layer slots — the paper §3.1's
"the executing **layer(s)**", plural, made tunable — while every (G, k,
pack) combination computes bit-identical results (tests/test_relay.py).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ExecutionConfig:
    n_microbatches: int = 1
    # --- L2L memory policies -------------------------------------------
    offload_stash: bool = False     # eq.(4): stash -> pinned_host
    weight_stream: bool = False     # EPS: params/opt live in pinned_host
    # --- storage-tier EPS (HBM <- pinned host <- mmap/NVMe) ---------------
    # tiers=2 is the historical two-tier placement; tiers=3 extends the
    # chain below host DRAM: the cold row tail of every stacked layer
    # group (weights + optimizer slots) lives in a verified on-disk
    # SegmentStore (core/tierstore.py — packed flat segments, per-row
    # crc32 manifests, staged-fsync-rename writes) and is re-materialized
    # around each jitted call through a prefetch ring that issues disk
    # reads ``prefetch_depth`` relay-stop chunks ahead.  Demotion is
    # driven by ``host_budget_bytes``: when the resident stacked state
    # would exceed it, coldest rows demote to disk instead of OOMing
    # (0 = no budget: demote everything — fully streamed).  Transient
    # read errors retry ``tier_retries`` times with exponential backoff
    # from ``tier_backoff_s``; checksum failures quarantine + rebuild
    # from the newest good checkpoint.  Bit-identical to tiers=2 across
    # the whole (G, prefetch, pack, K) grid (tests/test_tierstore.py).
    tiers: int = 2
    host_budget_bytes: int = 0      # resident stacked-state budget (tiers=3)
    tier_dir: str = ""              # SegmentStore root ("" = temp dir)
    tier_retries: int = 3
    tier_backoff_s: float = 0.01
    # --- constant-memory stash (every-K boundary checkpointing) ----------
    # K >= 1: the forward relay stashes only the boundary activations at
    # layer indices = 0 (mod K) within each group — ceil(N/K) boundaries
    # instead of N, so the stash (host OR device) stops growing linearly
    # with depth.  The reverse relay, on arriving at a K-segment,
    # re-streams that segment's weights forward through the relay executor
    # to recompute the K-1 missing boundaries from the last stored one,
    # then runs the recompute-vjp backward over the segment: a second
    # recompute tier (Chen-style sublinear checkpointing inside the relay)
    # costing one extra layer-forward for K-1 of every K layers.  K = 1 is
    # the historical stash-every-boundary schedule, byte-for-byte.
    stash_every: int = 1
    # --- scan over segments ----------------------------------------------
    # How the K > 1 stash segments become a program: True (default) drives
    # all of them through ONE outer lax.scan per phase (core/relay.py
    # ``segment_scan`` — a traced segment start feeds dynamic slices, the
    # short N-mod-K remainder runs outside the scan), so the lowered train
    # step holds an O(1)-in-depth number of relay/scan instances; False
    # re-emits the historical unrolled per-segment relays (~3·ceil(N/K)
    # scan instances) for compile-time A/Bs (benchmarks/fig_compile.py).
    # Bit-identical results either way (tests/test_stash.py runs the
    # whole grid against both).
    segment_scan: bool = True
    # --- runtime-dynamic depth -------------------------------------------
    # Depth as a RUNTIME value: the jitted step/grads/prefill/decode take
    # an extra traced ``n_layers`` operand (<= the config's capacity
    # depth); layers past it pass activations through untouched and keep
    # their params/optimizer rows bit-identical, under per-layer
    # ``lax.cond`` gating inside the relays (``relay_scan(active=...)``).
    # ONE compiled program serves every depth — zero recompiles while a
    # NAS loop grows the model (examples/nas_depth_growth.py) or a sweep
    # walks depths (examples/depth_scaling.py).  Single-group models
    # only; with stash_every = K > 1 the capacity depth must be a
    # multiple of K (the remainder split would be value-dependent).
    dynamic_depth: bool = False
    # --- relay pipelining -------------------------------------------------
    # 0 = fetch a relay stop's weights at the top of its own scan
    #     iteration (the copy is serialized with the stop's compute);
    # k >= 1: the scan carry holds a ring of k prefetched HBM slots whose
    #     host->device DMAs were issued k stops BEFORE their consumer
    #     iteration (stop i+k forward, i-k reverse), so up to k transfers
    #     overlap compute: one compute slot + k transfer slots in HBM.
    #     k = 1 is the historical double buffer.
    prefetch_depth: int = 0
    # --- layer-group scheduling -------------------------------------------
    # G >= 1 stacked layers relayed per stop: one DMA (one copy per leaf,
    # or per dtype segment with pack_params) covers G layers, the inner
    # microbatch loop runs the G-layer sub-stack, and reverse/trailing/
    # decode relays iterate group-wise (ceil(N/G) stops).  Device weight
    # footprint becomes G * (1 + prefetch_depth) layer slots — the
    # paper's "executing layer(s)" footprint traded against relay stops.
    layers_per_relay: int = 1
    # --- relay transport --------------------------------------------------
    # HOW a relay stop's slot physically moves between the EPS and HBM:
    # "xla" (historical) slices + ``device_put``s at scan boundaries and
    # trusts XLA's latency-hiding scheduler to overlap the copies;
    # "pallas" routes every stream-in AND write-back through the
    # double-buffered ``kernels/relay_copy`` DMA pipeline
    # (``pltpu.make_async_copy`` paced by two rotating semaphores), so
    # prefetch overlap is guaranteed by the kernel instead of scheduler
    # luck.  A pure transport change: bit-identical to "xla" across the
    # whole (G, prefetch, pack, K) grid (tests/test_transport.py).
    transport: str = "xla"
    # --- packed relay -----------------------------------------------------
    # Coalesce each layer's weight pytree (and, with eager_optimizer, its
    # optimizer-slot pytree) into contiguous per-dtype flat buffers
    # (core/packing.py), so every EPS relay issues ONE large DMA per layer
    # per direction instead of N small per-leaf copies, and the eager
    # optimizer runs as a fused flat-segment kernel
    # (kernels/fused_adam_flat) when the optimizer provides one.
    # Bit-identical to the unpacked schedule (tests/test_packing.py).
    pack_params: bool = False
    # --- L2L-p ----------------------------------------------------------
    eager_optimizer: bool = True    # Alg 4 (False = Alg 3)
    host_optimizer: bool = False    # run the optimizer on the EPS host
    #   (jax.experimental.compute_on("device_host") — the paper's CPU
    #   optimizer / eq. (6)'s O_tc, overlapped by the scheduler in L2L-p)
    # --- gradient clipping ----------------------------------------------
    clip_mode: str = "none"         # none | per_layer
    clip_norm: float = 1.0
    # --- anomaly sentinel -------------------------------------------------
    # Reject a whole optimizer step whose gradients contain a non-finite
    # value (inf/nan from bad data, numeric blowup, or injected faults):
    # the step returns the PRIOR state bit-identically — params, opt
    # slots AND the step counter — and reports it via the
    # ``skipped_steps`` metric (1 on a rejected step).  Works for every
    # engine with AMP off (the AMP path keeps its per-layer skip — eager
    # updates can't await a global verdict — and the loss scale still
    # adapts on rejected steps so overflow recovery converges); composes
    # with the full (G, prefetch, pack, K) knob grid.
    skip_nonfinite: bool = False
    # --- mixed precision (the paper's named future work: "automatic
    # mixed precision (FP16/FP32)") -----------------------------------------
    # 0 = disabled.  With a scale, the head cotangent is multiplied by it,
    # per-layer grads are unscaled before clip/update, and non-finite
    # layers SKIP their update (the L2L-adapted skip: eager per-layer
    # updates can't wait for a global finiteness verdict).
    loss_scale_init: float = 0.0
    loss_scale_growth: int = 200    # good steps before doubling
    # --- baseline-only ----------------------------------------------------
    remat: bool = False             # gradient checkpointing per layer
    # --- serving ---------------------------------------------------------
    decode_window: int = 0          # ring-buffer window (0 = full cache)
    # --- analysis ---------------------------------------------------------
    # fully unroll the layer scans: XLA's cost_analysis counts while-loop
    # bodies ONCE, so the dry-run's cost probes compile small unrolled
    # depths and extrapolate (see launch/dryrun.py).
    unroll_layers: bool = False

    def __post_init__(self):
        assert self.n_microbatches >= 1
        assert self.clip_mode in ("none", "per_layer")
        assert self.prefetch_depth >= 0, \
            "prefetch_depth: k in-flight relay slots (0 = no pipelining)"
        assert self.layers_per_relay >= 1, \
            "layers_per_relay: G >= 1 layers moved per relay stop"
        assert self.transport in ("xla", "pallas"), \
            "transport: 'xla' (device_put at scan boundaries) or " \
            "'pallas' (double-buffered DMA copy kernel)"
        assert self.stash_every >= 1, \
            "stash_every: K >= 1 layers per stashed boundary " \
            "(1 = stash every layer boundary)"
        assert self.segment_scan or not self.dynamic_depth, \
            "dynamic_depth needs the segment-scan driver (a traced " \
            "depth cannot gate unrolled per-segment programs)"
        assert self.tiers in (2, 3), \
            "tiers: 2 = HBM <- pinned host, 3 = + mmap/NVMe segment store"
        assert self.host_budget_bytes >= 0
        assert self.tier_retries >= 0
        assert self.tier_backoff_s >= 0.0
