"""Production mesh construction.

Functions only — importing this module never touches jax device state.
Target: TPU v5e, 256 chips/pod (16x16), 2 pods for the multi-pod dry-run.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 512 if multi_pod else 256
    devices = jax.devices()[:n]
    assert len(devices) == n, \
        (f"need {n} devices (set XLA_FLAGS=--xla_force_host_platform_"
         f"device_count=512 BEFORE importing jax); have {len(devices)}")
    import numpy as np
    dev_array = np.asarray(devices).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_debug_mesh(data: int = 2, model: int = 4):
    """Small mesh for tests (8 forced host devices)."""
    devices = jax.devices()[:data * model]
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(data, model),
                             ("data", "model"))


# Hardware constants for the roofline (TPU v5e)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
