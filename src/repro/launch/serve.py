"""Serving driver: continuous-batching by default, one-shot legacy mode.

    # continuous batching: paged KV, per-request join/leave, one relay
    # sweep per decode tick for all in-flight requests
    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --variant smoke --requests 8 --max-batch 4 --prompt-len 32 --gen 32

    # legacy fixed-batch path (prefill once, decode in lockstep)
    PYTHONPATH=src python -m repro.launch.serve --mode oneshot --batch 4

Demonstrates the L2L serving story through the Engine facade: with
--weight-stream the model's layer stack is EPS-resident and relayed per
layer during decode (TPU memory spaces; logical-only on CPU — see
eps.memories_supported).  Throughput is reported with compile time
separated out: the first tick/step pays the jit, steady-state tok/s does
not include it."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine as engines
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig
from repro.serve.engine import ServeConfig
from repro.serve.sampling import sample_batch


def default_page_size(max_seq):
    """Largest divisor of max_seq not above max_seq // 4 (>= 1), so the
    default paging always satisfies the divide constraint for arbitrary
    --prompt-len/--gen combinations."""
    p = max(1, max_seq // 4)
    while max_seq % p:
        p -= 1
    return p


def run_oneshot(eng, cfg, args):
    """Legacy path: one fixed batch, prefill then lockstep decode."""
    params = eng.model.init_params(jax.random.PRNGKey(args.seed))
    live = args.cache_len or (args.window if args.window
                              else args.prompt_len + args.gen)
    rng = jax.random.PRNGKey(args.seed + 1)
    prompt = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    frames = None
    if cfg.family == "audio":
        frames = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.n_frames, cfg.d_model)
        ).astype(jnp.bfloat16)

    def pick(logits, pos):
        return sample_batch(logits, temperature=args.temperature,
                            top_k=args.top_k, seed=args.seed,
                            position=pos)[:, None]

    t0 = time.perf_counter()
    caches, last_logits = eng.decode_init(params, prompt, live,
                                          frames=frames)
    jax.block_until_ready(last_logits)
    t_prefill = time.perf_counter() - t0

    tok = pick(last_logits, args.prompt_len - 1)
    out_tokens = [tok]
    # first decode step compiles the serve program — time it apart so the
    # steady-state rate is not diluted by the jit
    t0 = time.perf_counter()
    logits, caches = eng.decode_step(params, caches, tok,
                                     jnp.int32(args.prompt_len))
    tok = pick(logits[:, -1], args.prompt_len)
    out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_compile = time.perf_counter() - t0

    steady_steps = max(args.gen - 2, 0)
    t0 = time.perf_counter()
    for i in range(steady_steps):
        logits, caches = eng.decode_step(params, caches, tok,
                                         jnp.int32(args.prompt_len + 1 + i))
        tok = pick(logits[:, -1], args.prompt_len + 1 + i)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    toks = jnp.concatenate(out_tokens, axis=1)
    n_steady_tokens = args.batch * steady_steps
    print(f"arch={cfg.name} B={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen} cache={live} temp={args.temperature} "
          f"top_k={args.top_k}")
    print(f"prefill: {t_prefill:.2f}s  decode compile(+1st step): "
          f"{t_compile:.2f}s  steady decode: {t_decode:.2f}s "
          f"({n_steady_tokens} tok -> "
          f"{n_steady_tokens / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample:", np.asarray(toks[0, :16]).tolist())
    return toks


def run_continuous(eng, cfg, args):
    """Continuous batching: requests join/leave a shared slot pool; every
    decode tick is ONE relay sweep for all in-flight sequences."""
    params = eng.model.init_params(jax.random.PRNGKey(args.seed))
    max_seq = args.window or (args.prompt_len + args.gen)
    scfg = ServeConfig(
        max_batch=args.max_batch,
        page_size=args.page_size or default_page_size(max_seq),
        n_pages=args.n_pages or 4 * args.max_batch,
        max_seq=max_seq, prefill_chunk=args.prefill_chunk,
        max_pending=args.max_pending)
    srv = eng.serve_session(params, scfg)
    rng = np.random.RandomState(args.seed + 1)
    reqs = [srv.submit(rng.randint(0, cfg.vocab_size,
                                   size=(args.prompt_len,)),
                       args.gen, temperature=args.temperature,
                       top_k=args.top_k, seed=args.seed + i,
                       ttl=args.ttl)
            for i in range(args.requests)]

    t0 = time.perf_counter()
    srv.tick()                                   # compiles the tick
    t_compile = time.perf_counter() - t0
    t0 = time.perf_counter()
    srv.run()
    t_serve = time.perf_counter() - t0

    lat = [r.t_done - r.t_submit for r in reqs if r.t_done is not None]
    tok_lat = [b - a for r in reqs
               for a, b in zip(r.token_times, r.token_times[1:])]
    n_tok = sum(len(r.generated) for r in reqs)
    st = srv.stats()
    print(f"arch={cfg.name} requests={args.requests} "
          f"max_batch={scfg.max_batch} pages={scfg.n_pages}x"
          f"{scfg.page_size} prompt={args.prompt_len} gen={args.gen}")
    print(f"compile(+1st tick): {t_compile:.2f}s  serve: {t_serve:.2f}s "
          f"({n_tok} tok -> {n_tok / max(t_serve, 1e-9):.1f} tok/s, "
          f"{srv.n_ticks} ticks)  done={st['finished'] - st['evicted']} "
          f"rejected={st['rejected']} evicted={st['evicted']}")
    if tok_lat:
        print(f"per-token latency p50/p99: "
              f"{np.percentile(tok_lat, 50) * 1e3:.1f}/"
              f"{np.percentile(tok_lat, 99) * 1e3:.1f} ms")
    print(f"per-request latency p50/p99: {np.percentile(lat, 50):.2f}/"
          f"{np.percentile(lat, 99):.2f} s")
    print("sample:", reqs[0].generated[:16])
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("continuous", "oneshot"),
                    default="continuous")
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--batch", type=int, default=4,
                    help="oneshot: fixed decode batch")
    ap.add_argument("--requests", type=int, default=8,
                    help="continuous: number of requests to serve")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="continuous: in-flight slot pool size")
    ap.add_argument("--page-size", type=int, default=0,
                    help="continuous: KV page size (0 = max_seq/4)")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="continuous: KV page pool (0 = 4*max_batch)")
    ap.add_argument("--prefill-chunk", type=int, default=1,
                    help="continuous: prompt tokens per tick while "
                         "prefilling (recurrent families force 1)")
    ap.add_argument("--ttl", type=float, default=0.0,
                    help="continuous: per-request deadline in seconds — "
                         "requests still pending or mid-decode past it "
                         "are evicted and their slot/pages recycled "
                         "(0 = no deadline)")
    ap.add_argument("--max-pending", type=int, default=0,
                    help="continuous: admission bound — submits beyond "
                         "this many queued requests are rejected "
                         "(0 = unbounded)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples with per-request PRNG")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the k best logits (0 = off)")
    ap.add_argument("--weight-stream", action="store_true")
    ap.add_argument("--prefetch", type=int, default=0,
                    help="k-deep decode weight-relay prefetch ring (0 = "
                         "serialized fetch, 1 = double buffer)")
    ap.add_argument("--group", type=int, default=1,
                    help="G = layers per decode relay stop (one DMA "
                         "covers G stacked layers)")
    ap.add_argument("--pack", action="store_true",
                    help="packed decode relay: one flat buffer per layer "
                         "per dtype instead of per-leaf copies")
    ap.add_argument("--transport", default="xla",
                    choices=["xla", "pallas"],
                    help="decode relay slot mover: 'xla' device_put vs "
                         "'pallas' double-buffered DMA copy kernel")
    ap.add_argument("--window", type=int, default=0,
                    help="ring-buffer window (long-context mode)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, args.variant)
    eng = engines.create("l2l", cfg, ExecutionConfig(
        weight_stream=args.weight_stream, prefetch_depth=args.prefetch,
        layers_per_relay=args.group, pack_params=args.pack,
        transport=args.transport, decode_window=args.window))
    if args.mode == "oneshot" or cfg.family == "audio":
        return run_oneshot(eng, cfg, args)
    return run_continuous(eng, cfg, args)


if __name__ == "__main__":
    main()
