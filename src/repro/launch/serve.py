"""Serving driver: prefill a batch of prompts, then decode tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --variant smoke --batch 4 --prompt-len 32 --gen 32

Demonstrates the L2L serving story through the Engine facade: with
--weight-stream the model's layer stack is EPS-resident and relayed per
layer during decode (TPU memory spaces; logical-only on CPU — see
eps.memories_supported)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine as engines
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=0)
    ap.add_argument("--weight-stream", action="store_true")
    ap.add_argument("--prefetch", type=int, default=0,
                    help="k-deep decode weight-relay prefetch ring (0 = "
                         "serialized fetch, 1 = double buffer)")
    ap.add_argument("--group", type=int, default=1,
                    help="G = layers per decode relay stop (one DMA "
                         "covers G stacked layers)")
    ap.add_argument("--pack", action="store_true",
                    help="packed decode relay: one flat buffer per layer "
                         "per dtype instead of per-leaf copies")
    ap.add_argument("--window", type=int, default=0,
                    help="ring-buffer window (long-context mode)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, args.variant)
    eng = engines.create("l2l", cfg, ExecutionConfig(
        weight_stream=args.weight_stream, prefetch_depth=args.prefetch,
        layers_per_relay=args.group, pack_params=args.pack,
        decode_window=args.window))
    params = eng.model.init_params(jax.random.PRNGKey(args.seed))

    live = args.cache_len or (args.window if args.window
                              else args.prompt_len + args.gen)
    rng = jax.random.PRNGKey(args.seed + 1)
    prompt = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    frames = None
    if cfg.family == "audio":
        frames = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.n_frames, cfg.d_model)
        ).astype(jnp.bfloat16)

    t0 = time.time()
    caches, last_logits = eng.decode_init(params, prompt, live,
                                          frames=frames)
    jax.block_until_ready(last_logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, caches = eng.decode_step(params, caches, tok,
                                         jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    toks = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} B={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen} cache={live}")
    print(f"prefill: {t_prefill:.2f}s  decode: {t_decode:.2f}s "
          f"({args.batch * (args.gen - 1) / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample:", np.asarray(toks[0, :16]).tolist())
    return toks


if __name__ == "__main__":
    main()
