import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb runner (§Perf): re-lowers the chosen (arch x shape)
pairs with candidate optimizations and reports before/after roofline
terms.  Results land in experiments/dryrun/perf/ and EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.perf [--pair NAME] [--multi]
"""
import argparse
import json

from repro.configs.base import INPUT_SHAPES, get_config
from repro.launch.dryrun import roofline_terms, run_one

# (name, arch, shape, variants) — each variant is (label, kwargs for run_one)
EXPERIMENTS = [
    ("moe_ep", "deepseek-v2-lite-16b", "train_4k", [
        ("opt1_ep_constraint", {"cfg_patch": {"moe_ep_constraint": True}}),
        ("opt2_ep_cap_1_0", {"cfg_patch": {
            "moe_ep_constraint": True, "capacity_factor": 1.0}}),
    ]),
    ("moe_tp_grok", "grok-1-314b", "train_4k", [
        ("opt1_tp_constraint", {"cfg_patch": {"moe_ep_constraint": True}}),
        ("opt2_tp_cap_1_0", {"cfg_patch": {
            "moe_ep_constraint": True, "capacity_factor": 1.0}}),
    ]),
    ("gqa_decode", "granite-3-8b", "decode_32k", [
        ("opt1_grouped_attn", {"cfg_patch": {"grouped_decode_attn": True}}),
        ("opt2_grouped_attn_batchseq", {
            "cfg_patch": {"grouped_decode_attn": True},
            "rule_overrides": {"kv": None}}),
    ]),
    ("dense_train", "command-r-35b", "train_4k", [
        ("opt1_fullchunk", {"cfg_patch": {"attn_chunk": 0}}),
        ("opt2_chunk2048", {"cfg_patch": {"attn_chunk": 2048}}),
        ("opt3_chunk128", {"cfg_patch": {"attn_chunk": 128}}),
    ]),
]


def summarize(rec):
    if rec.get("status") != "ok":
        return rec.get("status", "?")
    r = rec["roofline"]
    return (f"compute={r['compute_s']*1e3:9.1f}ms "
            f"memory={r['memory_s']*1e3:9.1f}ms "
            f"collective={r['collective_s']*1e3:9.2f}ms "
            f"dom={r['dominant']:10s} useful={r['useful_flops_ratio']*100:5.1f}%")


def run_variant(arch, shape_name, multi, out_dir, label, kw):
    rec = run_one(arch, shape_name, multi, variant="full",
                  exec_overrides=kw.get("exec_overrides"),
                  rule_overrides=kw.get("rule_overrides"),
                  cfg_patch=kw.get("cfg_patch"))
    if rec["status"] == "ok":
        cfg = get_config(arch)
        if kw.get("cfg_patch"):
            cfg = cfg.replace(**kw["cfg_patch"])
        rec["roofline"] = roofline_terms(rec, cfg, INPUT_SHAPES[shape_name])
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{arch}__{shape_name}__{label}.json"),
              "w") as f:
        json.dump(rec, f, indent=1)
    print(f"  {label:28s} {summarize(rec)}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default="all")
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun/perf")
    args = ap.parse_args()

    for name, arch, shape_name, variants in EXPERIMENTS:
        if args.pair != "all" and args.pair != name:
            continue
        print(f"\n== {name}: {arch} x {shape_name} "
              f"({'multi' if args.multi else 'single'} pod)")
        run_variant(arch, shape_name, args.multi, args.out,
                    "baseline", {})
        for label, kw in variants:
            run_variant(arch, shape_name, args.multi, args.out, label, kw)


if __name__ == "__main__":
    main()
