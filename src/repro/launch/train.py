"""Training driver.

End-to-end single-host training through the Engine facade (L2L-p by
default, Alg-3 L2L or the baseline for comparison) on the synthetic LM
pipeline::

    PYTHONPATH=src python -m repro.launch.train --arch bert-large \
        --engine l2l-p --steps 300 --batch 32 --seq 128 --ub 4

On a real TPU pod this same driver runs under the production mesh with
``--mesh single|multi`` (sharded params, per-layer eager reduction); on CPU
it runs unsharded.  Checkpoints via the engine's save/restore.

Preemption safety: checkpoints are crash-consistent (staged + fsynced +
atomically renamed, crc32-verified on restore — ``repro.checkpoint.io``),
``--resume auto`` restarts from the newest snapshot that verifies, and
SIGTERM/SIGINT finish the in-flight step, save a snapshot plus a
``PREEMPTED.json`` marker, and exit cleanly — a killed-and-resumed run
reaches a final state bit-identical to an uninterrupted one
(tests/test_faults.py), because every step i is a pure function of
(state, batch(i)) with per-step-seeded data.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine as engines
from repro.checkpoint import io as ckpt_io
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig
from repro.data.synthetic import DataConfig, SyntheticLM, add_modality_stubs
from repro.optim.optimizers import get_optimizer, make_schedule

PREEMPT_MARKER = "PREEMPTED.json"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bert-large")
    ap.add_argument("--variant", default="smoke",
                    choices=["smoke", "full"])
    ap.add_argument("--engine", default="l2l",
                    choices=["l2l", "l2l-p", "baseline"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ub", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--lr-schedule", default="cosine")
    ap.add_argument("--optimizer", default="adam",
                    choices=["adam", "adamw", "lamb", "sgd"])
    ap.add_argument("--clip", type=float, default=1.0)
    ap.add_argument("--no-eager", action="store_true",
                    help="with --engine l2l: trailing optimizer (Alg 3) "
                         "instead of the eager L2L-p schedule")
    ap.add_argument("--offload-stash", action="store_true")
    ap.add_argument("--stash-every", type=int, default=1,
                    help="K = layers per stashed boundary: checkpoint "
                         "only every K-th layer-boundary activation "
                         "(ceil(N/K) instead of N) and recompute the "
                         "in-between boundaries during the reverse relay "
                         "by re-streaming each segment's weights forward "
                         "(1 = historical stash-every-layer)")
    ap.add_argument("--weight-stream", action="store_true")
    ap.add_argument("--prefetch", type=int, default=0,
                    help="k = depth of the EPS relay prefetch ring: the "
                         "DMA for relay stop i+k is issued while stop i "
                         "computes (0 = serialized fetch, 1 = the "
                         "classic double buffer, k>1 = deeper ring)")
    ap.add_argument("--group", type=int, default=1,
                    help="G = layers per relay stop: one DMA covers G "
                         "stacked layers and the microbatch loop runs "
                         "the G-layer sub-stack (device weight footprint "
                         "G*(1+prefetch) layer slots)")
    ap.add_argument("--pack", action="store_true",
                    help="packed relay: coalesce each layer into one "
                         "flat buffer per dtype (one DMA per layer per "
                         "direction) and run the eager optimizer fused "
                         "on the flat segments")
    ap.add_argument("--transport", default="xla",
                    choices=["xla", "pallas"],
                    help="relay slot mover: 'xla' = device_put at scan "
                         "boundaries (overlap by XLA's scheduler), "
                         "'pallas' = double-buffered make_async_copy DMA "
                         "pipeline (overlap enforced by kernel "
                         "semaphores; bit-identical)")
    ap.add_argument("--tiers", type=int, default=2, choices=[2, 3],
                    help="memory tier chain: 2 = HBM <- pinned host "
                         "(historical), 3 = + verified on-disk "
                         "SegmentStore — the cold stacked-state tail "
                         "lives on NVMe and is staged around every step "
                         "(bit-identical; self-healing from checkpoints)")
    ap.add_argument("--host-budget", type=int, default=0,
                    help="with --tiers 3: resident stacked-state byte "
                         "budget — layer rows beyond it demote to disk "
                         "coldest-first (0 = demote everything, the "
                         "fully-streamed mode)")
    ap.add_argument("--tier-dir", default="",
                    help="with --tiers 3: segment-store root directory "
                         "(default: a fresh temp dir)")
    ap.add_argument("--host-optimizer", action="store_true",
                    help="run the optimizer on the EPS host "
                         "(compute_on 'device_host')")
    ap.add_argument("--skip-nonfinite", action="store_true",
                    help="anomaly sentinel: reject any step whose "
                         "gradients contain inf/nan — params, opt slots "
                         "and step counter stay bit-identical and the "
                         "step is counted in skipped_steps")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--keep-last", type=int, default=0,
                    help="retention: keep only the newest N snapshots "
                         "(0 = keep all)")
    ap.add_argument("--resume", default="",
                    help="'auto' = restart from the newest VERIFIED "
                         "snapshot in --ckpt-dir (fresh run when none); "
                         "or an explicit checkpoint directory (errors "
                         "when it holds no good snapshot)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--step-delay-ms", type=int, default=0,
                    help="sleep after every step — widens the "
                         "kill/preemption window for the deterministic "
                         "fault-injection harness (repro.testing.faults)")
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M model)")
    ap.add_argument("--n-layers", type=int, default=0)
    ap.add_argument("--dynamic-depth", action="store_true",
                    help="depth as a RUNTIME value: the jitted step takes "
                         "a traced n_layers operand (--run-layers, default "
                         "the full capacity depth), so one compiled "
                         "program serves every depth <= capacity")
    ap.add_argument("--run-layers", type=int, default=0,
                    help="with --dynamic-depth: the runtime depth operand "
                         "(0 = the config's capacity depth)")
    args = ap.parse_args(argv)

    # historical CLI: "--engine l2l" means the eager L2L-p schedule unless
    # --no-eager asks for the Alg-3 trailing-update variant.
    engine_name = args.engine
    if engine_name == "l2l" and not args.no_eager:
        engine_name = "l2l-p"
    elif engine_name == "l2l-p" and args.no_eager:
        ap.error("--no-eager contradicts --engine l2l-p "
                 "(use --engine l2l --no-eager for Algorithm 3)")

    cfg = get_config(args.arch, args.variant)
    over = {"max_seq_len": max(cfg.max_seq_len, args.seq)}
    if args.d_model:
        over.update(d_model=args.d_model,
                    d_ff=args.d_model * 4,
                    n_heads=max(1, args.d_model // 64),
                    n_kv_heads=max(1, min(cfg.n_kv_heads,
                                          args.d_model // 64)))
    if args.n_layers:
        over["n_layers"] = args.n_layers
    cfg = cfg.replace(**over)

    opt = get_optimizer(
        args.optimizer,
        schedule=make_schedule(args.lr, warmup=args.warmup,
                               total=args.steps, kind=args.lr_schedule))
    exec_cfg = ExecutionConfig(
        n_microbatches=args.ub,
        offload_stash=args.offload_stash,
        stash_every=args.stash_every,
        weight_stream=args.weight_stream,
        prefetch_depth=args.prefetch,
        layers_per_relay=args.group,
        pack_params=args.pack,
        transport=args.transport,
        tiers=args.tiers,
        host_budget_bytes=args.host_budget,
        tier_dir=args.tier_dir,
        host_optimizer=args.host_optimizer,
        skip_nonfinite=args.skip_nonfinite,
        dynamic_depth=args.dynamic_depth,
        clip_mode="per_layer" if args.clip > 0 else "none",
        clip_norm=args.clip)
    if args.run_layers and not args.dynamic_depth:
        ap.error("--run-layers needs --dynamic-depth")
    run_layers = ((args.run_layers or cfg.n_layers)
                  if args.dynamic_depth else None)
    eng = engines.create(engine_name, cfg, exec_cfg, optimizer=opt)
    print(f"arch={cfg.name} engine={eng.name} params="
          f"{cfg.param_count()/1e6:.1f}M layers={cfg.n_layers} "
          f"d={cfg.d_model}")

    # ---- resume: newest verified snapshot wins; corrupt ones fall back
    start_step = 0
    resumed_from = None
    if args.resume:
        resume_dir = args.ckpt_dir if args.resume == "auto" else args.resume
        assert resume_dir, "--resume auto needs --ckpt-dir"
        good = ckpt_io.latest_good(resume_dir,
                                   fingerprint=eng.state_fingerprint())
        if good is not None:
            state, start_step = eng.restore(resume_dir, step=good)
            resumed_from = good
            print(f"resumed from {resume_dir} at step {start_step} "
                  f"(verified snapshot)", flush=True)
        elif args.resume != "auto":
            raise SystemExit(
                f"--resume {resume_dir}: no verifiable checkpoint")
        else:
            state = eng.init(jax.random.PRNGKey(args.seed))
    else:
        state = eng.init(jax.random.PRNGKey(args.seed))

    # ---- preemption: finish the in-flight step, save, exit resumable
    stop = {"sig": None}

    def _on_signal(signum, frame):
        stop["sig"] = signum

    old_handlers = {s: signal.signal(s, _on_signal)
                    for s in (signal.SIGTERM, signal.SIGINT)}

    def save_snapshot(step):
        eng.save(args.ckpt_dir, state, step=step,
                 keep_last=args.keep_last)
        return step

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch,
                                  seed=args.seed))
    losses = []
    skipped = 0
    compile_s = 0.0
    preempted = False
    last_saved = start_step if resumed_from is not None else None
    t0 = time.time()
    first = True
    for i in range(start_step, args.steps):
        # per-step seeded stub rng: batch(i) is a pure function of i, so
        # a resumed run replays the identical data stream
        rng = np.random.default_rng((args.seed, i))
        batch_np = add_modality_stubs(data.batch(i), cfg, rng)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        if run_layers is None:
            state, metrics = eng.train_step(state, batch)
        else:
            state, metrics = eng.train_step(state, batch, run_layers)
        loss = float(metrics["loss"])
        losses.append(loss)
        skipped += int(metrics.get("skipped_steps", 0))
        if first:
            # the FIRST EXECUTED step includes the jit compile — on a
            # fresh run and equally on a --resume run, whose new process
            # re-jits on its first step: report it separately on both
            # paths and restart the s/step clock so the average is
            # steady-state only.
            first = False
            compile_s = time.time() - t0
            t0 = time.time()
            print(f"step {i:5d}  loss {loss:8.4f}  gnorm "
                  f"{float(metrics['grad_norm']):8.3f}  "
                  f"(compile+first step: {compile_s:.2f}s)", flush=True)
        elif (i - start_step) % args.log_every == 0 or i == args.steps - 1:
            dt = time.time() - t0
            print(f"step {i:5d}  loss {loss:8.4f}  gnorm "
                  f"{float(metrics['grad_norm']):8.3f}  "
                  f"{dt/max(i - start_step, 1):.2f}s/step", flush=True)
        if args.step_delay_ms:
            time.sleep(args.step_delay_ms / 1e3)
        if args.ckpt_dir and args.ckpt_every and \
                (i + 1) % args.ckpt_every == 0:
            last_saved = save_snapshot(i + 1)
        if stop["sig"] is not None:
            # in-flight step finished above — snapshot and leave a
            # resumable marker, then exit cleanly
            preempted = True
            if args.ckpt_dir:
                if last_saved != i + 1:
                    last_saved = save_snapshot(i + 1)
                with open(os.path.join(args.ckpt_dir, PREEMPT_MARKER),
                          "w") as f:
                    json.dump({"step": i + 1, "signal": int(stop["sig"]),
                               "total_steps": args.steps}, f)
            break
    t_loop_end = time.time()
    for s, h in old_handlers.items():
        signal.signal(s, h)
    # final save — exactly once even when steps is divisible by
    # --ckpt-every (the loop's periodic save already covered it)
    if args.ckpt_dir and not preempted and last_saved != args.steps:
        last_saved = save_snapshot(args.steps)
    if args.ckpt_dir and not preempted:
        marker = os.path.join(args.ckpt_dir, PREEMPT_MARKER)
        if os.path.exists(marker):
            os.remove(marker)
    # steady-state s/step over the post-compile steps THIS process ran
    # (len(losses) counts executed steps: a resumed run starts empty)
    steady = (round((t_loop_end - t0) / (len(losses) - 1), 4)
              if len(losses) > 1 else None)
    print(json.dumps({"final_loss": losses[-1] if losses else None,
                      "mean_last10": (float(np.mean(losses[-10:]))
                                      if losses else None),
                      "initial_loss": losses[0] if losses else None,
                      "compile_s": round(compile_s, 2),
                      "steady_s_per_step": steady,
                      "run_layers": run_layers,
                      "steps": args.steps,
                      "final_step": int(state.step),
                      "resumed_from": resumed_from,
                      "preempted": preempted,
                      "skipped_steps": skipped,
                      "tier_metrics": (eng.tier.metrics
                                       if eng.tier is not None else None)}))
    return losses


if __name__ == "__main__":
    main()
