"""Training driver.

End-to-end single-host training through the Engine facade (L2L-p by
default, Alg-3 L2L or the baseline for comparison) on the synthetic LM
pipeline::

    PYTHONPATH=src python -m repro.launch.train --arch bert-large \
        --engine l2l-p --steps 300 --batch 32 --seq 128 --ub 4

On a real TPU pod this same driver runs under the production mesh with
``--mesh single|multi`` (sharded params, per-layer eager reduction); on CPU
it runs unsharded.  Checkpoints via the engine's save/restore.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine as engines
from repro.configs.base import get_config
from repro.core.schedule import ExecutionConfig
from repro.data.synthetic import DataConfig, SyntheticLM, add_modality_stubs
from repro.optim.optimizers import get_optimizer, make_schedule


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bert-large")
    ap.add_argument("--variant", default="smoke",
                    choices=["smoke", "full"])
    ap.add_argument("--engine", default="l2l",
                    choices=["l2l", "l2l-p", "baseline"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ub", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--lr-schedule", default="cosine")
    ap.add_argument("--optimizer", default="adam",
                    choices=["adam", "adamw", "lamb", "sgd"])
    ap.add_argument("--clip", type=float, default=1.0)
    ap.add_argument("--no-eager", action="store_true",
                    help="with --engine l2l: trailing optimizer (Alg 3) "
                         "instead of the eager L2L-p schedule")
    ap.add_argument("--offload-stash", action="store_true")
    ap.add_argument("--stash-every", type=int, default=1,
                    help="K = layers per stashed boundary: checkpoint "
                         "only every K-th layer-boundary activation "
                         "(ceil(N/K) instead of N) and recompute the "
                         "in-between boundaries during the reverse relay "
                         "by re-streaming each segment's weights forward "
                         "(1 = historical stash-every-layer)")
    ap.add_argument("--weight-stream", action="store_true")
    ap.add_argument("--prefetch", type=int, default=0,
                    help="k = depth of the EPS relay prefetch ring: the "
                         "DMA for relay stop i+k is issued while stop i "
                         "computes (0 = serialized fetch, 1 = the "
                         "classic double buffer, k>1 = deeper ring)")
    ap.add_argument("--group", type=int, default=1,
                    help="G = layers per relay stop: one DMA covers G "
                         "stacked layers and the microbatch loop runs "
                         "the G-layer sub-stack (device weight footprint "
                         "G*(1+prefetch) layer slots)")
    ap.add_argument("--pack", action="store_true",
                    help="packed relay: coalesce each layer into one "
                         "flat buffer per dtype (one DMA per layer per "
                         "direction) and run the eager optimizer fused "
                         "on the flat segments")
    ap.add_argument("--host-optimizer", action="store_true",
                    help="run the optimizer on the EPS host "
                         "(compute_on 'device_host')")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M model)")
    ap.add_argument("--n-layers", type=int, default=0)
    args = ap.parse_args(argv)

    # historical CLI: "--engine l2l" means the eager L2L-p schedule unless
    # --no-eager asks for the Alg-3 trailing-update variant.
    engine_name = args.engine
    if engine_name == "l2l" and not args.no_eager:
        engine_name = "l2l-p"
    elif engine_name == "l2l-p" and args.no_eager:
        ap.error("--no-eager contradicts --engine l2l-p "
                 "(use --engine l2l --no-eager for Algorithm 3)")

    cfg = get_config(args.arch, args.variant)
    over = {"max_seq_len": max(cfg.max_seq_len, args.seq)}
    if args.d_model:
        over.update(d_model=args.d_model,
                    d_ff=args.d_model * 4,
                    n_heads=max(1, args.d_model // 64),
                    n_kv_heads=max(1, min(cfg.n_kv_heads,
                                          args.d_model // 64)))
    if args.n_layers:
        over["n_layers"] = args.n_layers
    cfg = cfg.replace(**over)

    opt = get_optimizer(
        args.optimizer,
        schedule=make_schedule(args.lr, warmup=args.warmup,
                               total=args.steps, kind=args.lr_schedule))
    exec_cfg = ExecutionConfig(
        n_microbatches=args.ub,
        offload_stash=args.offload_stash,
        stash_every=args.stash_every,
        weight_stream=args.weight_stream,
        prefetch_depth=args.prefetch,
        layers_per_relay=args.group,
        pack_params=args.pack,
        host_optimizer=args.host_optimizer,
        clip_mode="per_layer" if args.clip > 0 else "none",
        clip_norm=args.clip)
    eng = engines.create(engine_name, cfg, exec_cfg, optimizer=opt)
    print(f"arch={cfg.name} engine={eng.name} params="
          f"{cfg.param_count()/1e6:.1f}M layers={cfg.n_layers} "
          f"d={cfg.d_model}")

    state = eng.init(jax.random.PRNGKey(args.seed))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch,
                                  seed=args.seed))
    rng = np.random.default_rng(args.seed)
    losses = []
    compile_s = 0.0
    t0 = time.time()
    for i in range(args.steps):
        batch_np = add_modality_stubs(data.batch(i), cfg, rng)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        state, metrics = eng.train_step(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if i == 0:
            # step 0 includes the jit compile: report it separately and
            # restart the s/step clock so the average is steady-state only.
            compile_s = time.time() - t0
            t0 = time.time()
            print(f"step {i:5d}  loss {loss:8.4f}  gnorm "
                  f"{float(metrics['grad_norm']):8.3f}  "
                  f"(compile+first step: {compile_s:.2f}s)", flush=True)
        elif i % args.log_every == 0 or i == args.steps - 1:
            dt = time.time() - t0
            print(f"step {i:5d}  loss {loss:8.4f}  gnorm "
                  f"{float(metrics['grad_norm']):8.3f}  "
                  f"{dt/i:.2f}s/step", flush=True)
        if args.ckpt_dir and args.ckpt_every and \
                (i + 1) % args.ckpt_every == 0:
            eng.save(args.ckpt_dir, state, step=i + 1)
    if args.ckpt_dir:
        eng.save(args.ckpt_dir, state, step=args.steps)
    print(json.dumps({"final_loss": losses[-1],
                      "mean_last10": float(np.mean(losses[-10:])),
                      "initial_loss": losses[0],
                      "compile_s": round(compile_s, 2),
                      "steps": args.steps}))
    return losses


if __name__ == "__main__":
    main()
