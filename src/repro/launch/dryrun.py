import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and extract the roofline terms.

The two lines above MUST stay the first statements in this module (before
any jax import) — jax locks the device count on first init.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh single            # 16x16 (256 chips) + roofline terms
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi  # 2x16x16

Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json and feed
EXPERIMENTS.md §Dry-run and §Roofline.
"""
import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from repro.configs.base import INPUT_SHAPES, get_config, list_archs
from repro.launch import mesh as mesh_mod
from repro.launch.build import SkipCombo, build

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
                "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1}

_SHAPE_RE = re.compile(r"=\s*(?:\()?\s*(\w+)\[([\d,]*)\]")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives, by op kind (result-shape
    convention: the bytes that land on each device)."""
    out = {c: {"count": 0, "bytes": 0} for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for c in COLLECTIVES:
            # match the op name after '=' e.g. '%x = bf16[..] all-reduce('
            if f" {c}(" in stripped or f" {c}-start(" in stripped:
                m = _SHAPE_RE.search(stripped)
                if not m:
                    continue
                dt, dims = m.group(1), m.group(2)
                nbytes = _DTYPE_BYTES.get(dt, 4)
                n = 1
                if dims:
                    for d in dims.split(","):
                        n *= int(d)
                out[c]["count"] += 1
                out[c]["bytes"] += n * nbytes
                break
    return out


def cost_get(ca, key: str) -> float:
    # jax returns cost_analysis() as a dict on recent versions, a
    # one-element list of dicts on older ones — accept both
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    return float(ca.get(key, 0.0)) if ca else 0.0


# ---------------------------------------------------------------------------
# Depth probes: XLA's cost_analysis counts while-loop bodies ONCE, so the
# scanned (depth-N) program under-reports FLOPs/bytes/collectives by the
# trip count.  We compile small UNROLLED depths (all-groups-1, then 2 for
# one group at a time) and extrapolate exactly linearly to the full depth.
# ---------------------------------------------------------------------------
def group_depths(cfg):
    if cfg.family == "audio":
        return (cfg.n_encoder_layers, cfg.n_layers)
    if cfg.family == "moe" and cfg.first_dense_layers:
        return (cfg.first_dense_layers,
                cfg.n_layers - cfg.first_dense_layers)
    return (cfg.n_layers,)


def with_depths(cfg, depths):
    if cfg.family == "audio":
        enc, dec = depths
        return cfg.replace(n_encoder_layers=enc, n_layers=dec)
    if cfg.family == "moe" and cfg.first_dense_layers:
        d0, d1 = depths
        return cfg.replace(first_dense_layers=d0, n_layers=d0 + d1)
    (d,) = depths
    return cfg.replace(n_layers=d)


def _cost_vector(built, mesh):
    with mesh:
        compiled = jax.jit(built.fn, in_shardings=built.in_shardings,
                           out_shardings=built.out_shardings
                           ).lower(*built.args).compile()
        ca = compiled.cost_analysis()
        hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)
    vec = {"flops": cost_get(ca, "flops"),
           "bytes_accessed": cost_get(ca, "bytes accessed")}
    for c, v in coll.items():
        vec[f"coll_bytes::{c}"] = float(v["bytes"])
        vec[f"coll_count::{c}"] = float(v["count"])
    return vec


def probe_costs(arch, shape_name, mesh, variant, exec_overrides,
                rule_overrides, cfg_patch=None):
    """Unrolled reduced-depth compiles + exact linear extrapolation."""
    cfg = get_config(arch, variant)
    if cfg_patch:
        cfg = cfg.replace(**cfg_patch)
    full = group_depths(cfg)
    G = len(full)
    probe_exec = dict(exec_overrides or {})
    # the probes compile tiny unrolled depths for cost extrapolation —
    # depth gating is irrelevant there (and depth-1 probes violate the
    # dynamic_depth capacity/K divisibility), so probe statically
    probe_exec.update(n_microbatches=1, unroll_layers=True,
                      dynamic_depth=False)
    base_depths = tuple(1 for _ in full)
    probes = [base_depths] + [
        tuple(2 if j == i else 1 for j in range(G)) for i in range(G)]
    vecs = []
    for d in probes:
        built = build(arch, shape_name, mesh, variant=variant,
                      exec_overrides=probe_exec,
                      rule_overrides=rule_overrides,
                      cfg_override=with_depths(cfg, d))
        vecs.append(_cost_vector(built, mesh))
    keys = vecs[0].keys()
    total = {}
    for k in keys:
        t = vecs[0][k]
        for i in range(G):
            delta = max(vecs[1 + i][k] - vecs[0][k], 0.0)
            t += (full[i] - 1) * delta
        total[k] = t
    # analytic correction: rwkv's wkv recurrence is a while loop over seq
    # even when layers are unrolled — its flops are added from the closed
    # form (6 * d * head_dim flops per token per layer, x4 for fwd+bwd+
    # recompute in training, x1 in inference).
    if cfg.family == "ssm":
        shape = INPUT_SHAPES[shape_name]
        dp = max(1, int(np.prod([mesh.shape[a] for a in ("pod", "data")
                                 if a in mesh.shape])))
        if shape.kind == "decode":
            toks_per_dev = shape.global_batch / min(dp, shape.global_batch)
        else:
            toks_per_dev = shape.global_batch * shape.seq_len / dp
        factor = 4.0 if shape.kind == "train" else 1.0
        wkv = (6.0 * cfg.d_model * cfg.rwkv_head_dim * toks_per_dev
               * cfg.n_layers * factor)
        total["flops"] += wkv
        total["wkv_analytic_flops"] = wkv
    return total, [dict(v) for v in vecs]


def run_one(arch: str, shape_name: str, multi_pod: bool,
            exec_overrides=None, rule_overrides=None, variant="full",
            probes: bool = True, cfg_patch=None) -> dict:
    t0 = time.time()
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "chips": n_chips,
           "status": "ok", "cfg_patch": cfg_patch or {}}
    cfg_override = None
    if cfg_patch:
        cfg_override = get_config(arch, variant).replace(**cfg_patch)
    try:
        built = build(arch, shape_name, mesh, variant=variant,
                      exec_overrides=exec_overrides,
                      rule_overrides=rule_overrides,
                      cfg_override=cfg_override)
    except SkipCombo as e:
        rec.update(status="skip", reason=str(e))
        return rec
    with mesh:
        jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                         out_shardings=built.out_shardings)
        lowered = jitted.lower(*built.args)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
    rec.update(
        meta=built.meta,
        memory={
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "host_argument_bytes": ma.host_argument_size_in_bytes,
            "host_temp_bytes": ma.host_temp_size_in_bytes,
            "host_output_bytes": ma.host_output_size_in_bytes,
        },
        collectives_scanned=parse_collective_bytes(hlo),
        hlo_bytes=len(hlo),
    )
    if probes:
        total, probe_vecs = probe_costs(arch, shape_name, mesh, variant,
                                        exec_overrides, rule_overrides,
                                        cfg_patch=cfg_patch)
        coll = {c: {"count": int(total.get(f"coll_count::{c}", 0)),
                    "bytes": int(total.get(f"coll_bytes::{c}", 0))}
                for c in COLLECTIVES}
        rec.update(
            cost={"flops": total["flops"],
                  "bytes_accessed": total["bytes_accessed"]},
            collectives=coll,
            probe_vectors=probe_vecs,
        )
    rec["compile_s"] = round(time.time() - t0, 1)
    return rec


def roofline_terms(rec: dict, cfg, shape) -> dict:
    """Per-device cost_analysis numbers -> the three roofline terms (s).

    Convention: compiled per-device HLO FLOPs/bytes ARE already the
    per-chip share, so term = per_device_quantity / per_chip_rate (equal to
    the spec's total/(chips*rate))."""
    peak = mesh_mod.PEAK_FLOPS_BF16
    hbm = mesh_mod.HBM_BW
    ici = mesh_mod.ICI_BW
    flops = rec["cost"]["flops"]
    byts = rec["cost"]["bytes_accessed"]
    cbytes = sum(v["bytes"] for v in rec["collectives"].values())
    compute_t = flops / peak
    memory_t = byts / hbm
    coll_t = cbytes / ici
    dom = max(("compute", compute_t), ("memory", memory_t),
              ("collective", coll_t), key=lambda kv: kv[1])[0]
    # model flops (useful work)
    n_active = cfg.param_count(active_only=True)
    chips = rec["chips"]
    if shape.kind == "train":
        D = shape.seq_len * shape.global_batch
        model_flops = 6 * n_active * D
    elif shape.kind == "prefill":
        D = shape.seq_len * shape.global_batch
        model_flops = 2 * n_active * D
    else:
        model_flops = 2 * n_active * shape.global_batch
    useful = model_flops / chips / max(flops, 1.0)
    return {"compute_s": compute_t, "memory_s": memory_t,
            "collective_s": coll_t, "collective_bytes_per_dev": cbytes,
            "dominant": dom, "model_flops_total": model_flops,
            "useful_flops_ratio": useful}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--variant", default="full")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf beyond-paper fixes (grouped GQA "
                         "decode + local MoE dispatch) on top of the "
                         "paper-faithful schedule")
    ap.add_argument("--prefetch", type=int, default=None,
                    help="override ExecutionConfig.prefetch_depth (the "
                         "build default is 1: double-buffered EPS relay): "
                         "0 compiles the serialized fetch-in-iteration "
                         "schedule, k >= 1 a k-deep prefetch ring — for "
                         "A/B HLO comparison across depths")
    ap.add_argument("--group", type=int, default=None,
                    help="override ExecutionConfig.layers_per_relay "
                         "(build default 1): relay G stacked layers per "
                         "stop — one DMA per stop covers the group; the "
                         "device weight footprint grows to G*(1+prefetch) "
                         "layer slots while the stop count drops to "
                         "ceil(N/G)")
    ap.add_argument("--pack", type=int, default=None, choices=[0, 1],
                    help="override ExecutionConfig.pack_params (build "
                         "default 0): 1 compiles the packed flat-buffer "
                         "relay — one host<->HBM copy per relay stop per "
                         "direction — for A/B HLO comparison")
    ap.add_argument("--stash-every", type=int, default=None,
                    help="override ExecutionConfig.stash_every (build "
                         "default 1): K > 1 compiles the constant-memory "
                         "stash — only every K-th layer boundary is "
                         "checkpointed (ceil(N/K) stashed) and the "
                         "reverse relay recomputes the rest by "
                         "re-streaming each K-segment forward — for A/B "
                         "host/device byte comparison")
    ap.add_argument("--transport", default=None,
                    choices=["xla", "pallas"],
                    help="override ExecutionConfig.transport (build "
                         "default 'xla'): 'pallas' lowers every relay "
                         "slot move through the double-buffered "
                         "make_async_copy DMA pipeline "
                         "(kernels/relay_copy) instead of scan-boundary "
                         "device_puts — for A/B of the emitted "
                         "copy/compute overlap structure")
    ap.add_argument("--n-layers", type=int, default=None,
                    help="override the arch's depth (layers of the main/"
                         "decoder group) — for depth sweeps of the "
                         "compiled program; the tag gains a -nN suffix "
                         "so sweep records never collide")
    ap.add_argument("--dynamic-depth", type=int, default=None,
                    choices=[0, 1],
                    help="override ExecutionConfig.dynamic_depth (build "
                         "default 0): 1 compiles the runtime-depth "
                         "program — the step takes a traced n_layers "
                         "operand and ONE compile serves every depth <= "
                         "capacity (single-group archs; tag suffix -dyn)")
    ap.add_argument("--segment-scan", type=int, default=None,
                    choices=[0, 1],
                    help="override ExecutionConfig.segment_scan (build "
                         "default 1): 0 compiles the historical unrolled "
                         "per-segment program (~3*ceil(N/K) relay "
                         "instances) for compile-time A/Bs against the "
                         "O(1)-in-depth segment-scan driver")
    ap.add_argument("--tiers", type=int, default=None, choices=[2, 3],
                    help="override ExecutionConfig.tiers (build default "
                         "2): 3 enables the storage-tier EPS — the cold "
                         "stacked-state tail lives in the on-disk "
                         "SegmentStore and is staged around each jitted "
                         "call.  The compiled program is identical (the "
                         "disk tier sits OUTSIDE jit); the A/B is over "
                         "the recorded exec metadata + the memory "
                         "model's host/disk byte split")
    args = ap.parse_args()
    cfg_patch = dict({"grouped_decode_attn": True, "moe_ep_constraint": True}
                     if args.optimized else {})
    if args.n_layers is not None:
        cfg_patch["n_layers"] = args.n_layers
    cfg_patch = cfg_patch or None
    exec_overrides = {}
    if args.prefetch is not None:
        exec_overrides["prefetch_depth"] = args.prefetch
    if args.group is not None:
        exec_overrides["layers_per_relay"] = args.group
    if args.pack is not None:
        exec_overrides["pack_params"] = bool(args.pack)
    if args.stash_every is not None:
        exec_overrides["stash_every"] = args.stash_every
    if args.tiers is not None:
        exec_overrides["tiers"] = args.tiers
    if args.transport is not None:
        exec_overrides["transport"] = args.transport
    if args.dynamic_depth is not None:
        exec_overrides["dynamic_depth"] = bool(args.dynamic_depth)
    if args.segment_scan is not None:
        exec_overrides["segment_scan"] = bool(args.segment_scan)
    exec_overrides = exec_overrides or None
    if args.optimized and args.tag == "baseline":
        args.tag = "optimized"
    # compose the knob values into the tag (with --optimized / custom
    # tags) so no A/B sweep ever overwrites another's records under the
    # same directory: every non-default multi-valued knob is spelled out
    if args.prefetch == 0:
        args.tag += "-noprefetch"
    elif args.prefetch is not None and args.prefetch != 1:
        args.tag += f"-pf{args.prefetch}"
    if args.group is not None and args.group != 1:
        args.tag += f"-g{args.group}"
    if args.pack == 1:
        args.tag += "-packed"
    if args.stash_every is not None and args.stash_every != 1:
        args.tag += f"-s{args.stash_every}"
    if args.tiers is not None and args.tiers != 2:
        args.tag += f"-t{args.tiers}"
    if args.transport == "pallas":
        args.tag += "-xcopy"
    # depth sweeps and dynamic-depth / unrolled-program A/Bs get their own
    # record directories too — two runs differing only in depth (or only
    # in the program driver) must never overwrite each other
    if args.n_layers is not None:
        args.tag += f"-n{args.n_layers}"
    if args.dynamic_depth == 1:
        args.tag += "-dyn"
    if args.segment_scan == 0:
        args.tag += "-unrolled"

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    archs = [a for a in archs if a != "bert-large"]
    shapes = (list(INPUT_SHAPES) if args.shape == "all"
              else args.shape.split(","))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for multi in meshes:
        mdir = os.path.join(args.out, args.tag,
                            "multi" if multi else "single")
        os.makedirs(mdir, exist_ok=True)
        for arch in archs:
            for shape_name in shapes:
                out_path = os.path.join(mdir, f"{arch}__{shape_name}.json")
                try:
                    rec = run_one(arch, shape_name, multi,
                                  variant=args.variant,
                                  exec_overrides=exec_overrides,
                                  cfg_patch=cfg_patch)
                    if rec["status"] == "ok":
                        cfg = get_config(arch, args.variant)
                        rec["roofline"] = roofline_terms(
                            rec, cfg, INPUT_SHAPES[shape_name])
                except Exception as e:   # noqa: BLE001 — report, keep going
                    rec = {"arch": arch, "shape": shape_name,
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                    failures.append((arch, shape_name, repr(e)))
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec.get("roofline", {})
                    extra = (f" compile={rec['compile_s']}s "
                             f"dom={r.get('dominant','?')}")
                print(f"[{'multi' if multi else 'single'}] "
                      f"{arch} x {shape_name}: {status}{extra}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for a, s, e in failures:
            print(f"  {a} x {s}: {e}")
        raise SystemExit(1)
    print("\nALL DRY-RUNS OK")


if __name__ == "__main__":
    main()
