"""Assemble (step_fn, abstract inputs, shardings) for every
(architecture x input shape x mesh) combination — the single source used by
the dry-run, the roofline, and the perf iterations.  All programs are
obtained through the Engine facade (``repro.engine``); this module only
adds the abstract inputs and explicit shardings the lowering needs.

Shape -> program mapping (see DESIGN.md §5 for the skips):

* train_4k    -> L2L-p train_step (weight relay + stash offload + eager opt)
* prefill_32k -> L2L prefill (layer-major forward relay)
* decode_32k  -> decode_step against a full-context KV cache / SSM state
* long_500k   -> decode_step with ring-buffer window (dense) or O(1) state
                 (ssm/hybrid); whisper: skipped
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import engine as engines
from repro.configs.base import (INPUT_SHAPES, InputShape, ModelConfig,
                                get_config)
from repro.core import packing
from repro.core.eps import memories_supported, pspecs_like
from repro.core.schedule import ExecutionConfig
from repro.distributed import sharding as shd
from repro.engine import TrainState
from repro.engine.placement import placements_for
from repro.models.model import LayeredModel, batch_spec, batch_dtypes
from repro.models.common import is_spec
from repro.optim import adam


class BuiltStep(NamedTuple):
    fn: Any                      # callable to jit
    args: tuple                  # abstract (ShapeDtypeStruct) args
    in_shardings: tuple
    out_shardings: Any           # or None for auto
    meta: dict                   # arch/shape/notes for reporting


SKIPS = {("whisper-base", "long_500k"):
         "enc-dec speech model: bounded source (1500 frames) and target "
         "positions; 524k-token decode is not meaningful for the family "
         "(DESIGN.md §5)"}


def microbatches_for(shape: InputShape, mesh) -> int:
    if shape.kind != "train":
        return 1
    dp = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                      if a in mesh.shape]))
    ub = 4
    while ub > 1 and (shape.global_batch // ub) % dp != 0:
        ub //= 2
    return ub


def live_cache_len(cfg: ModelConfig, shape: InputShape) -> int:
    """Ring-buffer size for decode shapes."""
    if cfg.family == "ssm":
        return 1                                  # state only, no KV slots
    if shape.name == "long_500k":
        w = cfg.sliding_window or cfg.long_context_window
        return min(w, shape.seq_len)
    if cfg.sliding_window:
        return min(cfg.sliding_window, shape.seq_len)
    return shape.seq_len


def decode_window(cfg: ModelConfig, shape: InputShape) -> int:
    if shape.name == "long_500k" and not cfg.sliding_window \
            and cfg.family != "ssm":
        return cfg.long_context_window
    return 0   # model default (cfg.sliding_window applies inside decode_ctx)


def _batch_abstract(cfg, shape):
    spec = batch_spec(cfg, shape)
    dts = batch_dtypes(cfg, shape)
    return {k: jax.ShapeDtypeStruct(s.shape, dts[k])
            for k, s in spec.items()}


def _batch_shardings(cfg, shape, mesh, rules):
    spec = batch_spec(cfg, shape)
    return {k: NamedSharding(mesh, shd.spec_to_pspec(s.axes, rules,
                                                     s.shape, mesh))
            for k, s in spec.items()}


def _opt_shardings_legacy(param_sh, opt_abs, mesh):
    """NamedShardings for the flat opt dict, mirroring the param ones.
    Packed groups ({slot: Packed} flat buffers) mirror the group buffers'
    replicated placement instead of the per-leaf pspec derivation."""
    def like(sh_tree, state_tree):
        pspecs = jax.tree.map(lambda s: s.spec, sh_tree)
        kinds = jax.tree.leaves(sh_tree)[0].memory_kind if jax.tree.leaves(
            sh_tree) else "device"
        ps = pspecs_like(pspecs, state_tree)
        return jax.tree.map(
            lambda p: NamedSharding(mesh, p, memory_kind=kinds), ps,
            is_leaf=lambda x: isinstance(x, P))

    def group(i):
        g_opt = opt_abs["groups"][i]
        if packing.opt_is_packed(g_opt):
            sh_leaves = jax.tree.leaves(param_sh["groups"][i])
            kind = sh_leaves[0].memory_kind if sh_leaves else "device"
            return jax.tree.map(
                lambda _: NamedSharding(mesh, P(), memory_kind=kind), g_opt)
        return like(param_sh["groups"][i], g_opt)

    return {
        "step": NamedSharding(mesh, P()),
        "embed": like(param_sh["embed"], opt_abs["embed"]),
        "head": like(param_sh["head"], opt_abs["head"]),
        "groups": tuple(group(i) for i in range(len(opt_abs["groups"]))),
    }


def make_exec_cfg(shape: InputShape, cfg: ModelConfig, mesh,
                  overrides: Optional[dict] = None) -> ExecutionConfig:
    base = dict(
        n_microbatches=microbatches_for(shape, mesh),
        offload_stash=(shape.kind == "train"),
        # stash every boundary by default; {"stash_every": K} / dryrun
        # --stash-every K checkpoints only every K-th boundary (ceil(N/K)
        # stashed) and recomputes the rest during the reverse relay
        stash_every=1,
        weight_stream=True,
        eager_optimizer=True,
        # production relays are double-buffered: the next stop's EPS DMA
        # is in flight while the current one computes (override
        # {"prefetch_depth": 0} for the serialized A/B baseline, k > 1
        # for a deeper ring)
        prefetch_depth=1,
        # one layer per relay stop by default; {"layers_per_relay": G} /
        # dryrun --group G relays G stacked layers per DMA, trading a
        # G*(1+prefetch) device footprint for ceil(N/G) relay stops
        layers_per_relay=1,
        # packed relay is opt-in here (override {"pack_params": True} /
        # dryrun --pack 1): flat buffers replicate over model axes, so on
        # tensor-parallel meshes it trades sharded weight residency for
        # one-DMA-per-layer relays
        pack_params=False,
        # two-tier placement by default; {"tiers": 3} / dryrun --tiers 3
        # extends the chain below host DRAM (verified on-disk
        # SegmentStore, staged around the jit boundary) — the COMPILED
        # program is unchanged, so dry-run A/Bs only differ in metadata
        # and the memory model's host/disk split
        tiers=2,
        decode_window=decode_window(cfg, shape),
    )
    if overrides:
        base.update(overrides)
    return ExecutionConfig(**base)


# ===========================================================================
# Builders
# ===========================================================================
def build(arch: str, shape_name: str, mesh, *, variant: str = "full",
          exec_overrides: Optional[dict] = None,
          rule_overrides: Optional[dict] = None,
          cfg_override: Optional[ModelConfig] = None) -> BuiltStep:
    shape = INPUT_SHAPES[shape_name]
    if (arch, shape_name) in SKIPS:
        raise SkipCombo(SKIPS[(arch, shape_name)])
    cfg = cfg_override or get_config(arch, variant)
    model = LayeredModel(cfg)
    kind = "decode" if shape.kind == "decode" else "train"
    rules = shd.make_rules(cfg, mesh, kind=kind,
                           batch_size=shape.global_batch)
    if rule_overrides:
        rules.update(rule_overrides)
    exec_cfg = make_exec_cfg(shape, cfg, mesh, exec_overrides)
    placements = placements_for(model, exec_cfg, mesh=mesh, rules=rules)

    # the production dry-run schedule is L2L-p unless the overrides asked
    # for the trailing (Alg-3) optimizer
    engine_name = "l2l-p" if exec_cfg.eager_optimizer else "l2l"
    eng = engines.create(engine_name, model, exec_cfg, optimizer=adam(),
                         mesh=mesh, rules=rules, placements=placements,
                         donate=False)

    params_abs = model.abstract_params()
    param_sh = shd.param_shardings(model, mesh, rules,
                                   weight_stream=exec_cfg.weight_stream)
    if exec_cfg.pack_params:
        # packed relay: the stacked groups become per-dtype flat buffers,
        # placed replicated over the model axes (see placements_for) in
        # the same memory space the unpacked groups used
        params_abs = jax.eval_shape(packing.pack_params, params_abs)
        # None (default space) for device residency — an explicit "device"
        # kind emits annotate custom calls the partitioner rejects (see
        # distributed.sharding.shardings)
        gkind = ("pinned_host"
                 if exec_cfg.weight_stream and memories_supported()
                 else None)
        param_sh = {**param_sh, "groups": jax.tree.map(
            lambda _: NamedSharding(mesh, P(), memory_kind=gkind),
            params_abs["groups"])}
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "engine": eng.name,
            "exec": dataclasses.asdict(eng.exec_cfg),
            "mesh": dict(mesh.shape)}

    # runtime-dynamic depth: the jitted programs take one extra traced
    # n_layers operand (replicated i32 scalar) — append it to every
    # abstract signature so the dry-run lowers the SAME program the
    # NAS/depth-sweep loops call at every depth
    dyn_args, dyn_sh = (), ()
    if exec_cfg.dynamic_depth:
        dyn_args = (jax.ShapeDtypeStruct((), jnp.int32),)
        dyn_sh = (NamedSharding(mesh, P()),)

    if shape.kind == "train":
        state_abs = eng.abstract_state()
        opt_sh = _opt_shardings_legacy(param_sh,
                                       state_abs.legacy_opt(), mesh)
        state_sh = TrainState.from_legacy(param_sh, opt_sh)
        batch_abs = _batch_abstract(cfg, shape)
        batch_sh = _batch_shardings(cfg, shape, mesh, rules)
        return BuiltStep(eng.step_fn, (state_abs, batch_abs) + dyn_args,
                         (state_sh, batch_sh) + dyn_sh,
                         (state_sh, None), meta)

    if shape.kind == "prefill":
        batch_abs = _batch_abstract(cfg, shape)
        batch_sh = _batch_shardings(cfg, shape, mesh, rules)
        return BuiltStep(eng.prefill_fn, (params_abs, batch_abs) + dyn_args,
                         (param_sh, batch_sh) + dyn_sh, None, meta)

    # decode
    from repro.core import decode as dec
    live = live_cache_len(cfg, shape)
    meta["live_cache"] = live
    caches_abs = dec.init_caches(model, shape.global_batch, live,
                                 abstract_only=True)
    cache_specs = model.cache_specs(shape.global_batch, live)
    cache_sh = tuple(
        jax.tree.map(lambda s: NamedSharding(
            mesh, shd.spec_to_pspec(s.axes, rules, s.shape, mesh)),
            spec, is_leaf=is_spec)
        for spec in cache_specs)
    token_abs = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    token_sh = NamedSharding(mesh, P(rules.get("batch")))
    pos_sh = NamedSharding(mesh, P())
    return BuiltStep(eng.decode_step_fn,
                     (params_abs, caches_abs, token_abs, pos_abs) + dyn_args,
                     (param_sh, cache_sh, token_sh, pos_sh) + dyn_sh,
                     (None, cache_sh), meta)


class SkipCombo(Exception):
    pass
