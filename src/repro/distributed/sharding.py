"""Logical-axis -> mesh-axis sharding rules.

Params/activations/caches declare logical axes (see models/common.py);
this module maps them onto the production mesh ("pod", "data", "model")
GSPMD-style, with divisibility-aware fallbacks (e.g. hymba's 25 heads or
whisper's 51865 vocab can't split 16 ways -> replicate that dim and rely on
the ffn/vocab dims that do divide).

Key placements:
  batch       -> ("pod","data")       (data parallel)
  heads/kv    -> "model"              (tensor parallel attention)
  ffn/expert_ffn -> "model"           (tensor parallel mlp)
  experts     -> "model"              (expert parallel, deepseek)
  vocab       -> "model"              (sharded embedding/logits)
  cache seq   -> "model"              (decode: distributed KV slots)
  layers      -> None                 (the L2L relay axis: never sharded)

``zero_shard_data`` additionally shards the stacked layer params over the
``data`` axis when the leading dims divide (beyond-paper, ZeRO-style EPS
partitioning — the paper's §2 notes L2L composes with ZeRO).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import is_spec


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


def _data_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape) or None


def make_rules(cfg, mesh: Mesh, *, kind: str = "train",
               batch_size: Optional[int] = None) -> dict:
    """Logical axis -> mesh axis (or tuple / None)."""
    model_ax = "model" if "model" in mesh.shape else None
    m = _axis_size(mesh, model_ax)
    data_ax = _data_axes(mesh)
    d = _axis_size(mesh, data_ax)

    def fits(n):
        return model_ax if (m > 1 and n % m == 0) else None

    rules = {
        "batch": data_ax if (batch_size is None or batch_size % d == 0)
        else None,
        "layers": None,
        "d_model": None,
        "heads": fits(cfg.n_heads),
        "kv": fits(cfg.n_kv_heads),
        "head_dim": None,
        "ffn": fits(cfg.d_ff),
        "expert_ffn": None,
        "experts": None,
        "vocab": fits(cfg.vocab_size),
        "heads_x_dim": fits(cfg.d_model),
        "lora": None,
        "state": None,
        "conv": None,
        "seq": None,
    }
    if cfg.n_experts:
        if m > 1 and cfg.n_experts % m == 0:
            rules["experts"] = model_ax          # expert parallel (deepseek)
            rules["expert_ffn"] = None
        else:
            rules["experts"] = None
            rules["expert_ffn"] = fits(cfg.d_ff_expert)  # TP inside experts
    if kind == "decode":
        # distributed KV cache: shard the seq slots over "model"; the kv
        # head dim stays replicated (can't double-use the axis).
        rules = dict(rules, seq=model_ax, kv=None, heads=rules["heads"])
    if kind == "hybrid_state":
        rules = dict(rules, ffn=fits(cfg.d_model))
    return rules


def spec_to_pspec(axes: tuple, rules: dict, shape: tuple = None,
                  mesh: Mesh = None) -> P:
    """axes: tuple of logical names (or None) per dim -> PartitionSpec.
    Ensures no mesh axis is used twice (later dims lose) and — when shape
    and mesh are given — drops assignments whose dim isn't divisible by
    the axis size (jax requires divisible input shardings)."""
    used = set()
    entries = []
    for i, ax in enumerate(axes):
        mesh_ax = rules.get(ax) if ax is not None else None
        flat = (mesh_ax if isinstance(mesh_ax, tuple)
                else (mesh_ax,) if mesh_ax else ())
        if mesh_ax is None or any(f in used for f in flat):
            entries.append(None)
            continue
        if shape is not None and mesh is not None:
            if shape[i] % _axis_size(mesh, mesh_ax) != 0:
                entries.append(None)
                continue
        used.update(flat)
        entries.append(mesh_ax)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def pspec_tree(spec_tree, rules: dict, mesh: Mesh = None):
    """ParamSpec tree -> PartitionSpec tree."""
    return jax.tree.map(
        lambda s: spec_to_pspec(s.axes, rules, s.shape, mesh),
        spec_tree, is_leaf=is_spec)


def shardings(spec_tree, rules: dict, mesh: Mesh, memory_kind=None):
    # memory_kind=None (default space) for device residency: an explicit
    # "device" kind makes jax emit annotate_device_placement custom calls
    # on outputs, which the SPMD partitioner rejects when unsharded.
    mk = None if memory_kind == "device" else memory_kind
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_to_pspec(s.axes, rules, s.shape,
                                                    mesh),
                                memory_kind=mk),
        spec_tree, is_leaf=is_spec)


def activation_pspec(rules: dict, with_ub: bool = False) -> P:
    """(B,S,d) or (UB,B,S,d) activations: batch data-parallel."""
    b = rules.get("batch")
    return P(None, b) if with_ub else P(b)


def batch_pspecs(cfg, shape, mesh, rules) -> dict:
    """PartitionSpecs for the input batch dict."""
    from repro.models.model import batch_spec
    return pspec_tree(batch_spec(cfg, shape), rules)


def param_shardings(model, mesh, rules, *, weight_stream=False,
                    zero_shard_data=False):
    """NamedShardings for the full param tree {"embed","head","groups"}.
    Groups go to pinned_host when weight_stream (the EPS residency)."""
    from repro.core.eps import memories_supported
    specs = model.param_specs()
    kind_groups = ("pinned_host" if (weight_stream and memories_supported())
                   else "device")
    emb = shardings(specs["embed"], rules, mesh)
    head = shardings(specs["head"], rules, mesh)
    g_rules = dict(rules)
    if zero_shard_data:
        g_rules["layers"] = _data_axes(mesh)
    groups = tuple(shardings(g, g_rules, mesh, memory_kind=kind_groups)
                   for g in specs["groups"])
    return {"embed": emb, "head": head, "groups": groups}


def layer_slice_pspecs(model, mesh, rules):
    """Per-group pspec tree for ONE layer (no stacked axis) — used by the
    EPS relay device_put inside the scans."""
    out = []
    for g in model.groups:
        out.append(pspec_tree(g.spec, rules))
    return tuple(out)
