"""Token sampling: greedy / temperature / top-k with per-request PRNG.

All paths are batched and jit-friendly — sampling runs INSIDE the serve
tick so the host only ever sees the chosen token ids.  Stochastic rows
derive their randomness from ``fold_in(PRNGKey(seed), position)``: a
request's stream depends only on its own (seed, position) pair, so the
same request replays the same tokens no matter which batch slot it lands
in or who else is in flight.  ``temperature == 0`` rows are exactly
``argmax`` (bit-identical to the historical greedy loop — the parity
tests pin this).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def fold_keys(seeds, positions):
    """Per-row PRNG keys: (B,) seeds x (B,) absolute positions -> (B,)
    keys (vmapped fold_in, so row b's key is independent of every other
    row)."""
    keys = jax.vmap(jax.random.PRNGKey)(seeds.astype(jnp.uint32))
    return jax.vmap(jax.random.fold_in)(
        keys, jnp.maximum(positions, 0).astype(jnp.uint32))


def sample(logits, seeds, positions, temperature, top_k):
    """(B, V) logits -> (B,) int32 tokens.

    temperature: (B,) float32 — 0 = greedy (exact argmax, no PRNG use).
    top_k:       (B,) int32   — 0 = full vocab; else keep the k best.
    seeds/positions: (B,) int32 — per-request PRNG stream (see module
    docstring); ignored on greedy rows.
    """
    V = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)

    # top-k: keep entries >= the k-th largest value (ties all kept — same
    # convention as the reference implementations)
    desc = -jnp.sort(-lf, axis=-1)
    k_eff = jnp.where(top_k > 0, top_k, V)
    k_idx = jnp.clip(k_eff - 1, 0, V - 1)
    thresh = jnp.take_along_axis(desc, k_idx[:, None], axis=1)
    masked = jnp.where(lf >= thresh, lf, NEG_INF)

    temp = jnp.maximum(temperature, 1e-6)[:, None]
    keys = fold_keys(seeds, positions)
    drawn = jax.vmap(jax.random.categorical)(keys, masked / temp)
    return jnp.where(temperature > 0, drawn.astype(jnp.int32), greedy)


def sample_batch(logits, *, temperature: float = 0.0, top_k: int = 0,
                 seed: int = 0, position=0):
    """Uniform-settings convenience for the one-shot serve path: every
    row shares (temperature, top_k) and the PRNG seed, but rows still
    draw independently (row index folded into the seed)."""
    B = logits.shape[0]
    seeds = jnp.full((B,), seed, jnp.int32) + jnp.arange(B, dtype=jnp.int32)
    pos = jnp.broadcast_to(jnp.asarray(position, jnp.int32), (B,))
    return sample(logits, seeds, pos,
                  jnp.full((B,), temperature, jnp.float32),
                  jnp.full((B,), top_k, jnp.int32))
