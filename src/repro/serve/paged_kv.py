"""Paged KV cache: fixed-size pages from a shared pool + per-slot tables.

The single-batch decode path keeps one contiguous ``(B, live, ...)`` cache
per layer.  For continuous batching that layout wastes HBM — every slot
pays for its worst-case context — and couples a request's lifetime to a
fixed batch row.  Here the sequence axis is cut into fixed-size **pages**
held in one pool per cache leaf::

    paged leaf   (n_layers, n_pages, page_size, ...)   # k/v/c/kr/pos
    slot leaf    (n_layers, max_batch, ...)            # SSM/conv states

and a **page table** ``(max_batch, pages_per_slot)`` of physical page ids
(-1 = unmapped) maps each batch slot's logical ring positions onto pool
pages.  The scheduler hands pages out from a free list and takes them back
when a request leaves; slots and pages are recycled without recompiling
anything — the tables are just int32 inputs of the jitted tick.

The decode kernels (``models.blocks.*_decode``) are reused unchanged: at
each relay stop the tick **gathers** a slot-contiguous view
``(B, pages_per_slot * page_size, ...)`` from the pool (logical page
order, so the view IS the historical contiguous cache), runs the layer's
decode on it, then **scatters back** only the positions written this tick.
Attention masks dead slots through the cache's own ``pos`` entries: the
gather fills unmapped pages' positions with -1, the same invalid marker
the ring buffer already uses, so no new masking path exists.

Composition: the ``decode_window`` ring is just ``pages_per_slot *
page_size == window`` (logical pages recycle as positions wrap); SSM /
hybrid recurrent state rides the per-slot (non-paged) leaves.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, is_spec, materialize


def is_paged_spec(spec: ParamSpec) -> bool:
    """A cache leaf pages iff it is laid out (batch, seq, ...) — the KV /
    compressed-KV / position leaves.  Per-slot recurrent state (SSM h,
    conv tails, RWKV wkv/shift) has no seq axis and stays slot-major."""
    return tuple(spec.axes[:2]) == ("batch", "seq")


class GroupPages(NamedTuple):
    """Static paging metadata for one decode group's cache tree."""
    spec: dict              # per-layer cache ParamSpec tree (batch=1 view)
    paged: dict             # same structure: bool per leaf


def _map_specs(fn, spec_tree, *trees):
    """tree_map over a ParamSpec-leaf tree zipped with value trees."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    flats = [treedef.flatten_up_to(t) for t in trees]
    out = [fn(s, *vals) for s, *vals in zip(leaves, *flats)]
    return jax.tree.unflatten(treedef, out)


def group_pages(model, max_batch: int, max_seq: int):
    """Per decode group: the per-layer cache spec at the serve shape and
    its paged/slot classification."""
    out = []
    for g in model.decode_groups():
        spec = g.cache_spec(max_batch, max_seq)
        paged = _map_specs(lambda s: is_paged_spec(s), spec)
        out.append(GroupPages(spec, paged))
    return tuple(out)


def pool_specs(model, *, max_batch: int, page_size: int, n_pages: int,
               max_seq: int):
    """Pooled ParamSpec trees, one per decode group, leaves stacked over
    the group's layers: paged leaves become (n_layers, n_pages, page_size,
    ...), slot leaves (n_layers, max_batch, ...)."""
    groups = group_pages(model, max_batch, max_seq)
    out = []
    for g, gp in zip(model.decode_groups(), groups):
        def one(spec, paged):
            if paged:
                shape = (g.n_layers, n_pages, page_size) + spec.shape[2:]
                axes = ("layers", "pages") + tuple(spec.axes[1:])
            else:
                shape = (g.n_layers,) + spec.shape
                axes = ("layers",) + tuple(spec.axes)
            return ParamSpec(shape, axes, spec.init, spec.scale)
        out.append(_map_specs(one, gp.spec, gp.paged))
    return tuple(out)


def init_pool(model, *, max_batch: int, page_size: int, n_pages: int,
              max_seq: int, dtype=None, rng=None):
    """Materialize the page pools (zeros for data, -1 for pos leaves)."""
    dtype = dtype or jnp.dtype(model.cfg.dtype)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    specs = pool_specs(model, max_batch=max_batch, page_size=page_size,
                       n_pages=n_pages, max_seq=max_seq)
    pools = []
    for spec in specs:
        tree = materialize(spec, rng, dtype)
        tree = _fix_pos_leaves(tree)
        pools.append(tree)
    return tuple(pools)


def _fix_pos_leaves(tree):
    """'pos' leaves are int32 and start invalid (-1)."""
    def walk(t):
        if isinstance(t, dict):
            return {k: (-jnp.ones(v.shape, jnp.int32) if k == "pos"
                        else walk(v)) for k, v in t.items()}
        return t
    return walk(tree)


# ---------------------------------------------------------------------------
# gather / scatter between the pool and slot-contiguous views
# ---------------------------------------------------------------------------
def gather_view(pool_layer, pages: GroupPages, table, page_size: int):
    """One layer's pool -> the contiguous (B, P*page_size, ...) per-slot
    view the decode kernels expect.  ``table``: (B, P) physical page ids,
    -1 = unmapped; unmapped pages read physical page 0 (clamped gather)
    but their ``pos`` entries are forced to -1, so attention masks them —
    the data leaves never need masking of their own."""
    B, P = table.shape
    safe = jnp.maximum(table, 0)
    mapped = jnp.repeat(table >= 0, page_size, axis=1)       # (B, P*ps)

    def one(spec, leaf):
        if not is_paged_spec(spec):
            return leaf
        g = jnp.take(leaf, safe, axis=0)                     # (B,P,ps,...)
        g = g.reshape((B, P * page_size) + leaf.shape[2:])
        if spec.axes == ("batch", "seq"):                    # the pos leaf
            g = jnp.where(mapped, g, -1)
        return g

    return _map_specs(one, pages.spec, pool_layer)


def scatter_new(pool_layer, new_view, pages: GroupPages, table, pos,
                active):
    """Write back ONE tick's updates: for paged leaves, only the slots
    written this tick (logical slot ``pos % (P*page_size)`` per row, the
    same ring arithmetic the decode kernels used) are scattered into their
    physical pages; rows with ``pos < 0`` (padding / inactive) and slots
    whose logical page is unmapped are dropped.  Per-slot leaves (SSM
    state) take the new value on active rows and keep the old elsewhere.

    pool_layer/new_view: one layer's trees;  table: (B, P) int32;
    pos: (B, T) int32 positions written this tick;  active: (B,) bool."""
    B, P = table.shape
    ps = None
    for s in jax.tree.leaves(pages.spec, is_leaf=is_spec):
        if is_paged_spec(s):
            ps = True
    if ps is None:                         # no paged leaves in this group
        def slot_only(spec, old, new):
            keep = active.reshape((B,) + (1,) * (old.ndim - 1))
            return jnp.where(keep, new.astype(old.dtype), old)
        return _map_specs(slot_only, pages.spec, pool_layer, new_view)

    page_size = None

    def one(spec, old, new):
        nonlocal page_size
        if not is_paged_spec(spec):
            keep = active.reshape((B,) + (1,) * (old.ndim - 1))
            return jnp.where(keep, new.astype(old.dtype), old)
        if page_size is None:
            page_size = old.shape[1]
        live = P * page_size
        valid = pos >= 0
        slot = jnp.mod(pos, live)                            # (B,T) logical
        logical_page = slot // page_size
        bidx = jnp.broadcast_to(jnp.arange(B)[:, None], pos.shape)
        phys = jnp.take_along_axis(
            jnp.where(table >= 0, table, old.shape[0]),      # OOB -> drop
            jnp.minimum(logical_page, P - 1), axis=1)
        phys = jnp.where(valid, phys, old.shape[0])          # OOB -> drop
        offset = jnp.mod(slot, page_size)
        vals = new[bidx, slot]                               # (B,T,...)
        return old.at[phys, offset].set(vals.astype(old.dtype),
                                        mode="drop")

    # page_size is derived from the first paged leaf encountered; all
    # paged leaves in a group share it by construction
    return _map_specs(one, pages.spec, pool_layer, new_view)


# ---------------------------------------------------------------------------
# claim-time resets (jitted once; page/slot id args are padded, OOB drops)
# ---------------------------------------------------------------------------
def reset_claim(pools, groups, page_ids, slot_ids):
    """Invalidate freshly claimed pages and zero the claimed slots' state.

    ``page_ids``: (R,) physical pages being handed to a new request — their
    pooled ``pos`` entries go to -1 so stale positions from the previous
    owner can never pass the attention mask.  ``slot_ids``: (Q,) batch
    slots being claimed — their per-slot (SSM) state leaves are zeroed.
    Pad both with -1 (mapped to an out-of-bounds index, dropped) to keep
    one compiled program for every admission."""
    out = []
    for pool, pages in zip(pools, groups):
        def one(spec, leaf):
            if is_paged_spec(spec):
                if spec.axes == ("batch", "seq"):            # pos leaf
                    n = leaf.shape[1]
                    ids = jnp.where(page_ids >= 0, page_ids, n)
                    return leaf.at[:, ids].set(-1, mode="drop")
                return leaf
            n = leaf.shape[1]
            ids = jnp.where(slot_ids >= 0, slot_ids, n)
            zeros = jnp.zeros((leaf.shape[0], ids.shape[0])
                              + leaf.shape[2:], leaf.dtype)
            return leaf.at[:, ids].set(zeros, mode="drop")
        out.append(_map_specs(one, pages.spec, pool))
    return tuple(out)


def pool_bytes(model, *, max_batch: int, page_size: int, n_pages: int,
               max_seq: int, cache_dtype_bytes: int = 2):
    """(kv_page_bytes, slot_state_bytes, n_paged_leaves) — the analytic
    footprint of the pools (memory_model's serve-mode terms)."""
    specs = pool_specs(model, max_batch=max_batch, page_size=page_size,
                       n_pages=n_pages, max_seq=max_seq)
    groups = group_pages(model, max_batch, max_seq)
    kv = slot = npaged = 0
    for spec_tree, gp in zip(specs, groups):
        flat_s = jax.tree.leaves(spec_tree, is_leaf=is_spec)
        flat_p = jax.tree.leaves(gp.paged)
        for s, paged in zip(flat_s, flat_p):
            size = 1
            for d in s.shape:
                size *= d
            # pos leaves are int32 (4B); data leaves ride the cache dtype
            nbytes = size * (4 if s.axes[-1] == "seq" and len(s.shape) == 3
                             and paged else cache_dtype_bytes)
            if paged:
                kv += nbytes
                npaged += 1
            else:
                slot += nbytes
    return kv, slot, npaged
