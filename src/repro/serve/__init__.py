"""Layer-major continuous-batching serve subsystem.

Continuous batching restated in L2L's layer-major order: every in-flight
sequence is pushed through each layer stop of ONE weight-relay sweep per
decode tick, so the per-layer EPS DMA is amortized over the whole
in-flight set instead of being a per-request tax.

* ``paged_kv``  — fixed-size KV pages from a shared pool, per-slot page
  tables, gather/scatter between the pool and the contiguous per-slot
  views the decode kernels consume.
* ``scheduler`` — host-side admission queue, slot pool and page
  allocator: requests join/leave mid-flight without recompiling.
* ``sampling``  — greedy / temperature / top-k sampling with a seeded
  PRNG threaded per request.
* ``engine``    — the jitted tick: one ``relay_scan`` sweep per decode
  step for all active slots, exposed through the Engine facade as
  ``Engine.serve_session``.
"""
from repro.serve.engine import ServeConfig, ServeEngine     # noqa: F401
from repro.serve.scheduler import Request, Scheduler        # noqa: F401
