"""Host-side request scheduler: admission, slot pool, page allocator.

The jitted serve tick has ONE compiled program per (max_batch, T, P)
shape; everything that changes as requests come and go — which slots are
live, where their pages sit, what token each row eats next — is plain
int32/bool tick INPUTS assembled here in numpy.  Joining and leaving
therefore never recompiles: a new request claims a free batch slot and a
page reservation, a finished one hands both back, and rows without an
owner ride along as padding (``pos = -1`` — masked by attention, writes
dropped).

Admission is reservation-based: a request enters only if the free pool
can cover its whole worst-case footprint ``ceil(min(prompt + gen,
capacity) / page_size)`` pages, so an admitted request can never
deadlock mid-decode; physical pages are then claimed lazily, one at a
time, as its positions actually cross page boundaries.  Under a
``decode_window`` ring the logical pages recycle (``pos`` wraps mod the
window) and the per-slot footprint is capped at ``pages_per_slot``.

Prefill rides the same sweep as decode: a prefilling slot contributes up
to ``prefill_chunk`` prompt tokens as extra query rows of the tick while
decoding slots contribute their single next token — there is no separate
prefill pass, and a prompt's last chunk samples its first generated
token in the very tick that consumes it.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, NamedTuple, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One serve request plus its runtime bookkeeping."""
    rid: int
    prompt: np.ndarray                 # (L,) int32 token ids
    max_new: int
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    # -- graceful degradation -------------------------------------------
    # a deadline from either clock evicts the request (pending or
    # mid-flight) and recycles its slot/pages; 0 = no deadline.
    ttl: float = 0.0                   # seconds since submit
    ttl_ticks: int = 0                 # scheduler ticks since submit

    # -- runtime (managed by the Scheduler) -----------------------------
    slot: int = -1
    n_cached: int = 0                  # tokens written into the cache
    generated: List[int] = dataclasses.field(default_factory=list)
    reserved_pages: int = 0            # reservation not yet claimed
    status: str = "queued"             # queued|active|done|evicted|rejected
    t_submit: float = 0.0
    tick_submit: int = 0
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.t_done is not None and self.status == "done"

    @property
    def evicted(self) -> bool:
        return self.status == "evicted"


class TickPlan(NamedTuple):
    """Fixed-shape arrays for one jitted tick (B = max_batch rows)."""
    tokens: np.ndarray      # (B, T) int32
    pos: np.ndarray         # (B, T) int32; -1 = padding row/slot
    table: np.ndarray       # (B, P) int32 physical page ids; -1 unmapped
    active: np.ndarray      # (B,)  bool — row owns live per-slot state
    last_idx: np.ndarray    # (B,)  int32 index in T of the last real token
    seeds: np.ndarray       # (B,)  int32 per-request PRNG seeds
    sample_pos: np.ndarray  # (B,)  int32 PRNG stream position
    temp: np.ndarray        # (B,)  float32
    top_k: np.ndarray       # (B,)  int32
    new_pages: np.ndarray   # (R,)  int32 pages claimed this tick (-1 pad)
    new_slots: np.ndarray   # (B,)  int32 slots claimed this tick (-1 pad)
    sample: np.ndarray      # (B,)  bool host-only: row emits a token
    n_tokens: int           # host-only: real tokens consumed this tick


class Scheduler:
    def __init__(self, *, max_batch: int, page_size: int, n_pages: int,
                 max_seq: int, prefill_chunk: int = 1, window: int = 0,
                 max_pending: int = 0):
        assert max_seq % page_size == 0, "page_size must divide max_seq"
        self.max_batch = max_batch
        self.page_size = page_size
        self.n_pages = n_pages
        self.max_seq = max_seq          # logical positions per slot
        self.T = max(1, prefill_chunk)
        self.P = max_seq // page_size   # pages per slot
        self.window = window
        self.max_pending = max_pending  # 0 = unbounded admission queue
        # a slot can cross at most this many page boundaries per tick
        self._claim_cap = max_batch * (-(-self.T // page_size) + 1)

        self.pending: deque = deque()
        self.active: Dict[int, Request] = {}
        self.finished: Dict[int, Request] = {}
        self.free_slots: List[int] = list(range(max_batch - 1, -1, -1))
        self.free_pages: List[int] = list(range(n_pages - 1, -1, -1))
        self.reserved = 0               # pages promised but not claimed
        self.table = -np.ones((max_batch, self.P), np.int32)
        self._plan: Optional[TickPlan] = None
        self._new_slots: List[int] = []  # claimed since the last tick
        self._next_rid = 0
        self.n_ticks = 0
        self.n_rejected = 0             # admissions refused at submit
        self.n_evicted = 0              # deadline-expired (pending+active)
        self._evicted_now: List[Request] = []

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new: int, *, temperature: float = 0.0,
               top_k: int = 0, seed: int = 0, now: float = 0.0,
               ttl: float = 0.0, ttl_ticks: int = 0) -> Request:
        """Queue a request.  ``ttl``/``ttl_ticks`` set a deadline
        (seconds / scheduler ticks since submit; 0 = none) after which
        the request is evicted wherever it is — still pending or
        mid-decode — and its slot/pages recycled.  When the admission
        queue is bounded (``max_pending``) and full, the request is
        REJECTED (``status == "rejected"``, counted in ``stats()``)
        instead of queued."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if not self.window and len(prompt) + 1 > self.max_seq:
            raise ValueError(
                f"prompt ({len(prompt)}) exceeds slot capacity "
                f"({self.max_seq}); use decode_window for longer contexts")
        req = Request(self._next_rid, prompt, max_new,
                      temperature=temperature, top_k=top_k, seed=seed,
                      ttl=ttl, ttl_ticks=ttl_ticks, t_submit=now,
                      tick_submit=self.n_ticks)
        self._next_rid += 1
        if self.max_pending and len(self.pending) >= self.max_pending:
            req.status = "rejected"
            req.t_done = now
            self.n_rejected += 1
            return req
        self.pending.append(req)
        # eager admission: claim a free slot right away so the pending
        # bound above only counts true overflow (the claimed slot's
        # reset rides the next tick's new_slots list)
        self._admit(now)
        return req

    def _need_pages(self, req: Request) -> int:
        total = len(req.prompt) + max(req.max_new - 1, 0)
        if not self.window:
            total = min(total, self.max_seq)
        return min(-(-total // self.page_size), self.P)

    def _admit(self, now: float) -> None:
        """FIFO admission; claimed slots accumulate in ``_new_slots``
        until the next planned tick resets them."""
        while (self.pending and self.free_slots
               and len(self.free_pages) - self.reserved
               >= self._need_pages(self.pending[0])):
            req = self.pending.popleft()
            req.slot = self.free_slots.pop()
            req.reserved_pages = self._need_pages(req)
            self.reserved += req.reserved_pages
            req.status = "active"
            self.active[req.slot] = req
            self._new_slots.append(req.slot)

    def _map_pages(self, req: Request, positions) -> List[int]:
        """Lazily claim physical pages for any unmapped logical page the
        given positions touch (ring pages are found already mapped after
        the first wrap and reused)."""
        claimed = []
        for p in positions:
            lp = (p % self.max_seq) // self.page_size
            if self.table[req.slot, lp] < 0:
                page = self.free_pages.pop()
                self.table[req.slot, lp] = page
                claimed.append(page)
                if req.reserved_pages > 0:
                    req.reserved_pages -= 1
                    self.reserved -= 1
        return claimed

    # -- graceful degradation: deadline eviction -----------------------
    def _expired(self, req: Request, now: float) -> bool:
        return ((req.ttl > 0 and now - req.t_submit >= req.ttl)
                or (req.ttl_ticks > 0
                    and self.n_ticks - req.tick_submit >= req.ttl_ticks))

    def _evict_expired(self, now: float) -> None:
        """Evict every pending or in-flight request past its deadline.
        An active eviction releases the slot and pages through the same
        path a normal finish does — the NEXT claimant of those pages
        resets them via the tick's claim-reset (``paged_kv.reset_claim``),
        so recycled pages are indistinguishable from fresh ones."""
        for req in [r for r in self.pending if self._expired(r, now)]:
            self.pending.remove(req)
            req.status = "evicted"
            req.t_done = now
            self.finished[req.rid] = req
            self.n_evicted += 1
            self._evicted_now.append(req)
        for req in [r for r in self.active.values()
                    if self._expired(r, now)]:
            self._release(req, now, status="evicted")
            self.n_evicted += 1
            self._evicted_now.append(req)

    def take_evicted(self) -> List[Request]:
        """Drain the requests evicted since the last call."""
        out, self._evicted_now = self._evicted_now, []
        return out

    # ------------------------------------------------------------------
    def plan_tick(self, now: float = 0.0) -> Optional[TickPlan]:
        """Assemble the next tick's inputs, or None when idle."""
        self.n_ticks += 1
        self._evict_expired(now)
        self._admit(now)
        # dedup: a slot claimed, evicted and re-claimed between ticks
        # appears once — one reset covers the current claimant
        new_slots_l = list(dict.fromkeys(self._new_slots))
        self._new_slots = []
        if not self.active:
            return None
        B, T = self.max_batch, self.T
        tokens = np.zeros((B, T), np.int32)
        pos = -np.ones((B, T), np.int32)
        active = np.zeros(B, bool)
        last_idx = np.zeros(B, np.int32)
        seeds = np.zeros(B, np.int32)
        sample_pos = np.zeros(B, np.int32)
        temp = np.zeros(B, np.float32)
        top_k = np.zeros(B, np.int32)
        sample = np.zeros(B, bool)
        new_pages_l: List[int] = []
        n_tokens = 0

        for slot, req in self.active.items():
            L = len(req.prompt)
            if req.n_cached < L:                        # prefill chunk
                t = min(T, L - req.n_cached)
                tokens[slot, :t] = req.prompt[req.n_cached:req.n_cached + t]
                pos[slot, :t] = np.arange(req.n_cached, req.n_cached + t)
                sample[slot] = (req.n_cached + t == L and req.max_new > 0)
            else:                                       # decode: one token
                t = 1
                tokens[slot, 0] = req.generated[-1]
                pos[slot, 0] = req.n_cached
                sample[slot] = True
            new_pages_l += self._map_pages(
                req, range(req.n_cached, req.n_cached + t))
            active[slot] = True
            last_idx[slot] = t - 1
            seeds[slot] = req.seed
            sample_pos[slot] = req.n_cached + t - 1
            temp[slot] = req.temperature
            top_k[slot] = req.top_k
            n_tokens += t

        new_pages = -np.ones(self._claim_cap, np.int32)
        new_pages[:len(new_pages_l)] = new_pages_l
        new_slots = -np.ones(B, np.int32)
        new_slots[:len(new_slots_l)] = new_slots_l
        self._plan = TickPlan(tokens, pos, self.table.copy(), active,
                              last_idx, seeds, sample_pos, temp, top_k,
                              new_pages, new_slots, sample, n_tokens)
        return self._plan

    # ------------------------------------------------------------------
    def record(self, sampled, now: float = 0.0) -> List[Request]:
        """Fold one tick's sampled tokens ((B,) int32) back into the
        request states; returns requests finished this tick."""
        plan, self._plan = self._plan, None
        assert plan is not None, "record() without a planned tick"
        done = []
        for slot, req in list(self.active.items()):
            if not plan.active[slot]:
                continue
            t = int(plan.last_idx[slot]) + 1
            req.n_cached += t
            if plan.sample[slot]:
                req.generated.append(int(sampled[slot]))
                if req.t_first is None:
                    req.t_first = now
                req.token_times.append(now)
            out_of_room = (not self.window
                           and req.n_cached >= self.max_seq)
            if len(req.generated) >= req.max_new or out_of_room:
                self._finish(req, now)
                done.append(req)
        return done

    def _release(self, req: Request, now: float, status: str):
        """Hand a request's slot and pages back to the pools (shared by
        normal completion and deadline eviction)."""
        req.t_done = now
        req.status = status
        del self.active[req.slot]
        self.free_slots.append(req.slot)
        for lp in range(self.P):
            page = int(self.table[req.slot, lp])
            if page >= 0:
                self.free_pages.append(page)
        self.table[req.slot] = -1
        self.reserved -= req.reserved_pages
        req.reserved_pages = 0
        self.finished[req.rid] = req
        req.slot = -1

    def _finish(self, req: Request, now: float):
        self._release(req, now, status="done")

    # ------------------------------------------------------------------
    @property
    def idle(self) -> bool:
        return not self.pending and not self.active

    def stats(self) -> dict:
        return {"pending": len(self.pending), "active": len(self.active),
                "finished": len(self.finished),
                "free_pages": len(self.free_pages),
                "reserved_pages": self.reserved,
                "free_slots": len(self.free_slots),
                "rejected": self.n_rejected,
                "evicted": self.n_evicted}
