"""The serve tick: ONE relay sweep per decode step for every live slot.

``make_serve_tick`` is ``core.decode.make_serve_step`` restated over the
paged pool: the SAME ``relay_scan`` (G-layer grouping, k-deep prefetch
ring, packed flat-buffer transport all unchanged) walks the layer stack
once per tick, and at each stop the body gathers the slot-contiguous
cache view from the page pool, runs the group's unmodified decode kernel
for ALL in-flight requests at once, and scatters this tick's new entries
back.  Per-layer EPS DMA cost is therefore paid once per tick, not once
per request — the layer-major continuous-batching claim this subsystem
exists to demonstrate.

Everything dynamic (tokens, positions, page tables, active mask, claim
lists, sampling knobs) enters as fixed-shape arrays from the Scheduler,
so the tick compiles exactly once per (max_batch, prefill_chunk,
pages_per_slot) and requests join/leave mid-flight for free.  Sampling
(greedy / temperature / top-k, per-request PRNG streams) happens inside
the jit; pools are donated, so steady-state serve memory is constant.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.relay import Stream, relay_scan
from repro.serve import paged_kv, sampling
from repro.serve.scheduler import Request, Scheduler


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Shape of the serve session (all static — they pick the ONE
    compiled tick program).

    * ``max_seq``  — logical cache positions per slot; must equal
      ``decode_window`` when the engine decodes with a ring.
    * ``n_pages``  — physical page pool shared by all slots; admission
      blocks (never deadlocks) when reservations would exceed it.
    * ``prefill_chunk`` — prompt tokens a prefilling slot feeds per tick
      (extra query rows on the same sweep); recurrent families (ssm /
      hybrid) are strictly single-token and force 1.
    """
    max_batch: int = 4
    page_size: int = 8
    n_pages: int = 32
    max_seq: int = 64
    prefill_chunk: int = 1
    # host-side admission bound (not a shape knob): submits beyond this
    # many queued-but-unadmitted requests are rejected, not queued
    # (0 = unbounded).  Rejections/evictions show up in ``stats()``.
    max_pending: int = 0


def make_serve_tick(model, exec_cfg, placements, serve_cfg: ServeConfig):
    """Returns tick(params, pools, plan-arrays) -> (tokens, new_pools)."""
    PF = exec_cfg.prefetch_depth
    PK = exec_cfg.pack_params
    G = exec_cfg.layers_per_relay
    page_size = serve_cfg.page_size
    dgroups = model.decode_groups()
    gidx = [i for i, g in enumerate(model.groups) if not g.is_encoder]
    gpages = paged_kv.group_pages(model, serve_cfg.max_batch,
                                  serve_cfg.max_seq)

    def tick(params, pools, tokens, pos, table, active, last_idx, seeds,
             sample_pos, temp, top_k, new_pages, new_slots):
        # claim-time hygiene first: new pages' pos -> -1, new slots'
        # recurrent state -> 0 (both no-ops when the id lists are padding)
        pools = paged_kv.reset_claim(pools, gpages, new_pages, new_slots)
        static = {"embed": params["embed"], "head": params["head"]}
        x = model.decode_embed(static, tokens, pos)
        ctx = model.decode_ctx(pos, window=exec_cfg.decode_window)
        new_pools = []
        for di, group in enumerate(dgroups):
            wp = placements.weights[gidx[di]]
            gp = gpages[di]

            def body(x_c, slots, pool_l, _g=group, _gp=gp):
                (w,) = slots
                if PK:
                    w = packing.unpack(w)
                view = paged_kv.gather_view(pool_l, _gp, table, page_size)
                x2, new_view = _g.decode(w, x_c, view, None, ctx)
                pool2 = paged_kv.scatter_new(pool_l, new_view, _gp, table,
                                             pos, active)
                return x2, pool2

            x, np_ = relay_scan(
                body, x, (Stream(wp, params["groups"][gidx[di]]),),
                xs=pools[di], group=G, prefetch=PF,
                unroll=exec_cfg.unroll_layers)
            new_pools.append(np_)
        logits = model.decode_logits(static, x)              # (B, T, V)
        idx = last_idx[:, None, None]
        last = jnp.take_along_axis(
            logits, jnp.broadcast_to(idx, (logits.shape[0], 1,
                                           logits.shape[2])), axis=1)[:, 0]
        toks = sampling.sample(last, seeds, sample_pos, temp, top_k)
        return toks, tuple(new_pools)

    return tick


class ServeEngine:
    """A continuous-batching serve session over an existing Engine.

    Owns the page pools, the Scheduler and the jitted tick; the Engine
    contributes its model, ExecutionConfig and EPS placements, so every
    relay knob (weight_stream / prefetch / group / pack / window)
    composes with serving unchanged::

        srv = eng.serve_session(params, ServeConfig(max_batch=8))
        srv.submit(prompt_ids, max_new=32)
        finished = srv.run()              # tick until idle
        finished[0].generated             # -> token ids
    """

    def __init__(self, engine, params, serve_cfg: Optional[ServeConfig]
                 = None):
        serve_cfg = serve_cfg or ServeConfig()
        model = engine.model
        fam = model.cfg.family
        if fam == "audio":
            raise NotImplementedError(
                "continuous-batching serve does not cover the audio "
                "family (encoder cross-KV is per-request, not paged)")
        if fam in ("ssm", "hybrid") and serve_cfg.prefill_chunk != 1:
            # recurrent state admits exactly one token per step
            serve_cfg = dataclasses.replace(serve_cfg, prefill_chunk=1)
        window = engine.exec_cfg.decode_window
        if window and serve_cfg.max_seq != window:
            raise ValueError(
                f"ServeConfig.max_seq ({serve_cfg.max_seq}) must equal "
                f"decode_window ({window}) — the ring IS the slot")
        if serve_cfg.max_seq % serve_cfg.page_size:
            raise ValueError("page_size must divide max_seq")
        P = serve_cfg.max_seq // serve_cfg.page_size
        if serve_cfg.n_pages < P:
            raise ValueError(
                f"n_pages ({serve_cfg.n_pages}) cannot back even one "
                f"slot ({P} pages)")

        self.engine = engine
        self.model = model
        self.cfg = serve_cfg
        self.params = engine._relay_params(params)
        self.scheduler = Scheduler(
            max_batch=serve_cfg.max_batch, page_size=serve_cfg.page_size,
            n_pages=serve_cfg.n_pages, max_seq=serve_cfg.max_seq,
            prefill_chunk=serve_cfg.prefill_chunk, window=window,
            max_pending=serve_cfg.max_pending)
        self.pools = paged_kv.init_pool(
            model, max_batch=serve_cfg.max_batch,
            page_size=serve_cfg.page_size, n_pages=serve_cfg.n_pages,
            max_seq=serve_cfg.max_seq)
        self._tick = jax.jit(
            make_serve_tick(model, engine.exec_cfg, engine.placements,
                            serve_cfg),
            donate_argnums=(1,))
        self._t0 = time.monotonic()
        self.n_ticks = 0
        self.tokens_out = 0

    # ------------------------------------------------------------------
    def _now(self) -> float:
        return time.monotonic() - self._t0

    def submit(self, prompt, max_new: int, **kw) -> Request:
        """Queue a request.  ``ttl=`` (seconds) / ``ttl_ticks=`` set a
        deadline after which it is evicted — pending or mid-flight — and
        its slot/pages recycled; ``Request.status`` tells how it ended
        (done / evicted / rejected)."""
        return self.scheduler.submit(prompt, max_new, now=self._now(),
                                     **kw)

    def tick(self) -> List[Request]:
        """Run one relay sweep for all live slots; returns the requests
        that left the system this tick — finished normally or evicted at
        their deadline (empty when idle or none left)."""
        plan = self.scheduler.plan_tick(now=self._now())
        evicted = self.scheduler.take_evicted()
        if plan is None:
            return evicted
        toks, self.pools = self._tick(
            self.params, self.pools, plan.tokens, plan.pos, plan.table,
            plan.active, plan.last_idx, plan.seeds, plan.sample_pos,
            plan.temp, plan.top_k, plan.new_pages, plan.new_slots)
        toks = np.asarray(toks)                  # sync point
        self.n_ticks += 1
        self.tokens_out += int(plan.sample.sum())
        return evicted + self.scheduler.record(toks, now=self._now())

    def run(self, max_ticks: int = 100_000) -> List[Request]:
        """Tick until every submitted request has finished."""
        done: List[Request] = []
        for _ in range(max_ticks):
            if self.scheduler.idle:
                break
            done.extend(self.tick())
        else:
            raise RuntimeError(f"serve did not drain in {max_ticks} ticks")
        return done

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        out = dict(self.scheduler.stats())
        out.update(ticks=self.n_ticks, tokens_out=self.tokens_out,
                   elapsed_s=self._now())
        return out
