"""Functional per-layer optimizers.

The L2L Eager Param-Server applies the optimizer ONE LAYER AT A TIME inside
the reverse scan (Algorithm 4), so the optimizer API is per-subtree::

    state = opt.init(params_subtree)
    new_params, new_state = opt.update(grads, state, params_subtree, step)

States are pytrees that mirror the param subtree leaf-for-leaf (each leaf
maps to a dict of slots), so a stacked layer group's optimizer state is
itself stacked and can be scanned/streamed exactly like the weights
(the paper's EPS holds params + optimizer state in host DRAM; eq. (1)'s
"4x" term).

Implemented: adam, adamw, lamb (the paper's future-work large-batch
optimizer [10]), sgd(+momentum).  All support an ``lr`` schedule function of
``step`` and optional per-call gradient scaling.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    name: str
    init: Callable        # params_subtree -> state_subtree
    update: Callable      # (grads, state, params, step) -> (new_params, new_state)
    # Optional fused update over FLAT 1-D segments (the packed relay's
    # pack_params path): (p, g, m, v, step) -> (p', m', v') where all
    # arrays are same-length 1-D buffers (g/m/v f32, p any dtype).  Must
    # be bit-identical to ``update`` applied leaf-wise — asserted by
    # tests/test_packing.py.  None = no fused form; the packed path then
    # falls back to unpack -> per-leaf update -> repack.
    flat_update: Optional[Callable] = None


def make_schedule(base_lr: float, warmup: int = 0, total: int = 0,
                  kind: str = "constant") -> Callable:
    def sched(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        lr = jnp.asarray(base_lr, jnp.float32)
        if warmup > 0:
            lr = lr * jnp.minimum(1.0, (s + 1.0) / warmup)
        if kind == "cosine" and total > 0:
            frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
            lr = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        elif kind == "linear" and total > 0:
            frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
            lr = lr * (1.0 - frac)
        return lr
    return sched


def tree_global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_norm(tree, max_norm: float):
    """Clip a gradient subtree by its own global norm (the L2L-p compatible
    per-layer clip — see DESIGN.md: a *global* clip would serialize the
    eager updates)."""
    norm = tree_global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def _cast_like(x, ref):
    return x.astype(ref.dtype)


def adam(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8,
         schedule: Callable | None = None) -> Optimizer:
    sched = schedule or (lambda s: lr)

    def init(params):
        return jax.tree.map(
            lambda p: {"m": jnp.zeros_like(p, jnp.float32),
                       "v": jnp.zeros_like(p, jnp.float32)}, params)

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        a = sched(step) * jnp.sqrt(1.0 - b2 ** t) / (1.0 - b1 ** t)

        def leaf(g, s, p):
            gf = g.astype(jnp.float32)
            m = b1 * s["m"] + (1 - b1) * gf
            v = b2 * s["v"] + (1 - b2) * gf * gf
            newp = p.astype(jnp.float32) - a * m / (jnp.sqrt(v) + eps)
            return _cast_like(newp, p), {"m": m, "v": v}

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state)
        flat_p = treedef.flatten_up_to(params)
        out = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_s = jax.tree.unflatten(treedef, [o[1] for o in out])
        return new_p, new_s

    return Optimizer("adam", init, update,
                     flat_update=_fused_flat_update(sched, b1, b2, eps, 0.0,
                                                    wd_form=False))


def _fused_flat_update(sched, b1, b2, eps, wd, wd_form) -> Callable:
    """Flat-segment Adam/AdamW: the fused Pallas kernel
    (kernels/fused_adam_flat through ops.fused_adam — one read and one
    write per (p, g, m, v) stream) on TPU; the kernel's exact elementwise
    chain in plain jnp elsewhere (interpret-mode Pallas pays a grid-loop
    tax XLA-compiled elementwise code doesn't — same split as
    eps.memories_supported).  The effective step size ``a`` and each
    elementwise term mirror the per-leaf path exactly, so packed and
    unpacked updates are bit-identical (tests/test_packing.py; the kernel
    itself is parity-tested in tests/test_kernels.py).  ``wd_form`` keys
    the update association on the optimizer FAMILY — adamw keeps its
    `a*(m/d + wd*p)` form even at weight_decay=0, where adam's `(a*m)/d`
    differs in the last ulp."""
    def flat_update(p, g, m, v, step):
        t = step.astype(jnp.float32) + 1.0
        a = sched(step) * jnp.sqrt(1.0 - b2 ** t) / (1.0 - b1 ** t)
        if jax.default_backend() == "tpu":
            from repro.kernels import ops as kops
            return kops.fused_adam(p, g, m, v, a, jnp.float32(1.0),
                                   b1=b1, b2=b2, eps=eps, wd=wd,
                                   wd_form=wd_form)
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        pf = p.astype(jnp.float32)
        if wd_form:
            newp = pf - a * (m2 / (jnp.sqrt(v2) + eps) + wd * pf)
        else:
            newp = pf - a * m2 / (jnp.sqrt(v2) + eps)
        return _cast_like(newp, p), m2, v2
    return flat_update


def adamw(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01,
          schedule: Callable | None = None) -> Optimizer:
    sched = schedule or (lambda s: lr)
    base = adam(lr, b1, b2, eps, schedule)

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        a = sched(step) * jnp.sqrt(1.0 - b2 ** t) / (1.0 - b1 ** t)

        def leaf(g, s, p):
            gf = g.astype(jnp.float32)
            m = b1 * s["m"] + (1 - b1) * gf
            v = b2 * s["v"] + (1 - b2) * gf * gf
            upd = m / (jnp.sqrt(v) + eps) + weight_decay * p.astype(jnp.float32)
            return _cast_like(p.astype(jnp.float32) - a * upd, p), \
                {"m": m, "v": v}

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state)
        flat_p = treedef.flatten_up_to(params)
        out = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        return (jax.tree.unflatten(treedef, [o[0] for o in out]),
                jax.tree.unflatten(treedef, [o[1] for o in out]))

    return Optimizer("adamw", base.init, update,
                     flat_update=_fused_flat_update(sched, b1, b2, eps,
                                                    weight_decay,
                                                    wd_form=True))


def lamb(lr=1e-3, b1=0.9, b2=0.999, eps=1e-6, weight_decay=0.01,
         schedule: Callable | None = None) -> Optimizer:
    """LAMB [You et al. 2019] — the paper's pointer for 32K-batch L2L-p."""
    sched = schedule or (lambda s: lr)
    base = adam(lr, b1, b2, eps, schedule)

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        a = sched(step)

        def leaf(g, s, p):
            gf = g.astype(jnp.float32)
            m = b1 * s["m"] + (1 - b1) * gf
            v = b2 * s["v"] + (1 - b2) * gf * gf
            mhat = m / (1.0 - b1 ** t)
            vhat = v / (1.0 - b2 ** t)
            u = mhat / (jnp.sqrt(vhat) + eps) + \
                weight_decay * p.astype(jnp.float32)
            w_norm = jnp.linalg.norm(p.astype(jnp.float32).reshape(-1))
            u_norm = jnp.linalg.norm(u.reshape(-1))
            trust = jnp.where(w_norm > 0,
                              jnp.where(u_norm > 0, w_norm / u_norm, 1.0),
                              1.0)
            return _cast_like(p.astype(jnp.float32) - a * trust * u, p), \
                {"m": m, "v": v}

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state)
        flat_p = treedef.flatten_up_to(params)
        out = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        return (jax.tree.unflatten(treedef, [o[0] for o in out]),
                jax.tree.unflatten(treedef, [o[1] for o in out]))

    return Optimizer("lamb", base.init, update)


def sgd(lr=1e-2, momentum=0.0, schedule: Callable | None = None) -> Optimizer:
    sched = schedule or (lambda s: lr)

    def init(params):
        if momentum == 0.0:
            return jax.tree.map(lambda p: {}, params)
        return jax.tree.map(
            lambda p: {"mu": jnp.zeros_like(p, jnp.float32)}, params)

    def update(grads, state, params, step):
        a = sched(step)

        def leaf(g, s, p):
            gf = g.astype(jnp.float32)
            if momentum == 0.0:
                return _cast_like(p.astype(jnp.float32) - a * gf, p), s
            mu = momentum * s["mu"] + gf
            return _cast_like(p.astype(jnp.float32) - a * mu, p), {"mu": mu}

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state)
        flat_p = treedef.flatten_up_to(params)
        out = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        return (jax.tree.unflatten(treedef, [o[0] for o in out]),
                jax.tree.unflatten(treedef, [o[1] for o in out]))

    return Optimizer("sgd", init, update)


def get_optimizer(name: str, **kw) -> Optimizer:
    return {"adam": adam, "adamw": adamw, "lamb": lamb, "sgd": sgd}[name](**kw)
