from repro.optim.optimizers import (Optimizer, adam, adamw, lamb, sgd,
                                    make_schedule, clip_by_norm,
                                    tree_global_norm)

__all__ = ["Optimizer", "adam", "adamw", "lamb", "sgd", "make_schedule",
           "clip_by_norm", "tree_global_norm"]
