"""Pure-jnp oracles for every Pallas kernel (the tests' ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def ref_attention(q, k, v, *, causal=True, window=0, soft_cap=0.0):
    """q,k,v: (B,H,S,D) — naive full-materialization attention."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if soft_cap > 0:
        s = soft_cap * jnp.tanh(s / soft_cap)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    allow = jnp.ones((Sq, Sk), bool)
    if causal:
        allow &= kp <= qp
    if window > 0:
        allow &= (qp - kp) < window
    s = jnp.where(allow, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def ref_adam(p, g, m, v, a, clip_scale, *, b1=0.9, b2=0.999, eps=1e-8,
             wd=0.0):
    gf = g.astype(jnp.float32) * clip_scale
    m2 = b1 * m + (1 - b1) * gf
    v2 = b2 * v + (1 - b2) * gf * gf
    upd = m2 / (jnp.sqrt(v2) + eps) + wd * p.astype(jnp.float32)
    return (p.astype(jnp.float32) - a * upd).astype(p.dtype), m2, v2


def ref_rmsnorm(x, scale, *, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)
