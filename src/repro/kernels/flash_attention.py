"""Pallas TPU flash attention (forward).

The L2L recompute path runs each layer's forward TWICE (eq. 6) — so the
attention forward is the hottest kernel in the schedule and the paper's
"higher effective TFLOPs from memory savings" argument lands exactly here:
blockwise online-softmax keeps the (Sq, Sk) score matrix out of HBM and the
working set in VMEM, sized by the BlockSpecs below.

Grid: (B, H, nQ, nK) — the innermost nK dimension iterates KV blocks while
VMEM scratch (m, l, acc) carries the online-softmax state across them; the
output block is written on the last KV block.  Causal and sliding-window
masks are computed from global block indices (no mask tensors in HBM), and
fully-masked (q,k) block pairs are skipped via the mask check inside —
on TPU the index_map still walks them, so the causal speedup comes from the
early-exit ``wrap`` below being compiled into a cheap branch.

Layouts: q,k,v as (B, H, S, D) with D and the S blocks aligned to the MXU
(block defaults 128/512 lanes).  fp32 accumulation regardless of input dtype.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _block_mask(iq, ik, block_q, block_k, causal, window):
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    allow = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        allow &= k_pos <= q_pos
    if window > 0:
        allow &= (q_pos - k_pos) < window
    return allow


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int, soft_cap: float,
               block_q: int, block_k: int, n_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
    if soft_cap > 0:
        s = soft_cap * jnp.tanh(s / soft_cap)

    allow = _block_mask(iq, ik, block_q, block_k, causal, window)
    s = jnp.where(allow, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
        p, v_ref[0, 0].astype(jnp.float32),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_scr[...] + jnp.log(l)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "soft_cap", "block_q", "block_k", "interpret"))
def flash_attention_fwd_bhsd(q, k, v, *, causal=True, window=0,
                             soft_cap=0.0, block_q=128, block_k=128,
                             interpret=True):
    """q,k,v: (B,H,S,D) -> (o (B,H,S,D), lse (B,H,S))."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, \
        f"seq ({Sq},{Sk}) must tile by ({block_q},{block_k})"
    n_q, n_k = Sq // block_q, Sk // block_k
    scale = 1.0 / math.sqrt(D)

    kern = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        soft_cap=soft_cap, block_q=block_q, block_k=block_k, n_k=n_k)

    return pl.pallas_call(
        kern,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ),
        out_shape=(jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
                   jax.ShapeDtypeStruct((B, H, Sq), jnp.float32)),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention_bhsd(q, k, v, *, causal=True, window=0, soft_cap=0.0,
                         block_q=128, block_k=128, interpret=True):
    """Forward-only convenience wrapper -> o (B,H,S,D)."""
    o, _ = flash_attention_fwd_bhsd(
        q, k, v, causal=causal, window=window, soft_cap=soft_cap,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return o


# ===========================================================================
# Backward (flash-attention-2 style): recompute p from (q,k,lse); no stored
# probability blocks — this is the §Perf "memory-bound train" lever: the
# jnp chunked attention's scan-vjp stashes fp32 p blocks (~3.4 s of the
# command-r train_4k memory term); the kernel recomputes them in VMEM.
# dq kernel: grid (B,H,nQ,nK), dq accumulates in VMEM scratch over k blocks.
# dkv kernel: grid (B,H,nK,nQ), dk/dv accumulate over q blocks.
# delta = rowsum(do * o) is a cheap jnp elementwise pass.
# ===========================================================================
def _fa_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                  dq_scr, *, scale, causal, window, block_q, block_k, n_k):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]
    delta = delta_ref[0, 0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    allow = _block_mask(iq, ik, block_q, block_k, causal, window)
    s = jnp.where(allow, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    dq_scr[...] += jnp.dot(ds, k, preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _flush():
        dq_ref[0, 0] = (dq_scr[...] * scale).astype(dq_ref.dtype)


def _fa_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dk_ref, dv_ref, dk_scr, dv_scr, *, scale, causal,
                   window, block_q, block_k, n_q):
    ik = pl.program_id(2)
    iq = pl.program_id(3)

    @pl.when(iq == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]
    delta = delta_ref[0, 0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    allow = _block_mask(iq, ik, block_q, block_k, causal, window)
    s = jnp.where(allow, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])                          # (bq, bk)
    dv_scr[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    dk_scr[...] += jnp.dot(ds.T, q, preferred_element_type=jnp.float32)

    @pl.when(iq == n_q - 1)
    def _flush():
        # q arrived pre-scaled, so ds^T @ qs already carries the 1/sqrt(D)
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_bwd_bhsd(q, k, v, o, lse, do, *, causal=True, window=0,
                             block_q=128, block_k=128, interpret=True):
    """-> (dq, dk, dv), all (B,H,S,D).  soft_cap unsupported in bwd (the
    models that train with the kernel don't cap; grok's capped logits are
    in the head, not attention)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    n_q, n_k = Sq // block_q, Sk // block_k
    scale = 1.0 / math.sqrt(D)
    delta = (do.astype(jnp.float32) * o.astype(jnp.float32)).sum(-1)

    q_spec_q = pl.BlockSpec((1, 1, block_q, D),
                            lambda b, h, iq, ik: (b, h, iq, 0))
    k_spec_q = pl.BlockSpec((1, 1, block_k, D),
                            lambda b, h, iq, ik: (b, h, ik, 0))
    r_spec_q = pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq))

    dq = pl.pallas_call(
        functools.partial(_fa_dq_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          n_k=n_k),
        grid=(B, H, n_q, n_k),
        in_specs=[q_spec_q, k_spec_q, k_spec_q, q_spec_q, r_spec_q,
                  r_spec_q],
        out_specs=q_spec_q,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    q_spec_k = pl.BlockSpec((1, 1, block_q, D),
                            lambda b, h, ik, iq: (b, h, iq, 0))
    k_spec_k = pl.BlockSpec((1, 1, block_k, D),
                            lambda b, h, ik, iq: (b, h, ik, 0))
    r_spec_k = pl.BlockSpec((1, 1, block_q), lambda b, h, ik, iq: (b, h, iq))

    dk, dv = pl.pallas_call(
        functools.partial(_fa_dkv_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          n_q=n_q),
        grid=(B, H, n_k, n_q),
        in_specs=[q_spec_k, k_spec_k, k_spec_k, q_spec_k, r_spec_k,
                  r_spec_k],
        out_specs=(k_spec_k, k_spec_k),
        out_shape=(jax.ShapeDtypeStruct((B, H, Sk, D), k.dtype),
                   jax.ShapeDtypeStruct((B, H, Sk, D), v.dtype)),
        scratch_shapes=[pltpu.VMEM((block_k, D), jnp.float32),
                        pltpu.VMEM((block_k, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
