"""Pallas double-buffered relay copy — the ``transport="pallas"`` slot mover.

The relay executor (``core.relay``) historically moves each stop's slot
with ``jax.device_put`` at scan boundaries and relies on XLA's
latency-hiding scheduler to keep the ring's copies in flight while a slot
computes.  That works, but the overlap is a scheduler HEURISTIC — nothing
in the emitted program *forces* the stop-``i+1`` stream-in to proceed
while stop ``i``'s layers run.  This kernel makes the copy itself a
Pallas DMA pipeline, the ``emit_pipeline`` idiom by hand:

* the slot arrives as a stacked ``(N, W)`` row-major buffer (exactly what
  ``core.packing``'s per-dtype flat segments are — one contiguous DMA
  operand; unpacked pytree leaves are reshaped to the same layout),
* the copy is split into a static chunk plan (one chunk per stacked row
  for multi-row slots; single-row slots split the row in half so two DMAs
  can still overlap),
* chunks are moved by ``pltpu.make_async_copy`` through TWO rotating DMA
  semaphores: chunk ``i``'s wait is interleaved with chunk ``i+2``'s
  start, so two transfers are always in flight — overlap guaranteed by
  the semaphores, not by scheduler luck.

On TPU the source lives in host/ANY memory and the copy is a real
host->HBM DMA; on CPU (this container / CI) the kernel runs in interpret
mode and the semantics — bit-exact movement of rows ``[start, start+size)``
— are what the transport tests pin down.  The kernel never needs a
custom VJP: ``relay_scan``'s fetch is not differentiated (the backward
vjp closes over the already-fetched slot), and the write-back direction
is an identity copy on the produced values.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _chunk_plan(size: int, width: int) -> tuple:
    """Static (row, col_lo, col_hi) DMA chunks for a (size, width) slot.

    Multi-row slots move one chunk per stacked row (relay rows are large
    — one packed dtype segment each — so per-row DMAs pipeline well);
    a single-row slot is split into two half-row chunks so the two DMA
    semaphores still have two transfers to rotate through.
    """
    if size >= 2 or width < 2:
        return tuple((r, 0, width) for r in range(size))
    h = width // 2
    return ((0, 0, h), (0, h, width))


def _copy_kernel(start_ref, src_ref, dst_ref, sems, *, chunks):
    s = start_ref[0]

    def dma(idx):
        r, c0, c1 = chunks[idx]
        return pltpu.make_async_copy(
            src_ref.at[pl.ds(s + r, 1), pl.ds(c0, c1 - c0)],
            dst_ref.at[pl.ds(r, 1), pl.ds(c0, c1 - c0)],
            sems.at[idx % 2])

    n = len(chunks)
    for i in range(min(2, n)):
        dma(i).start()
    for i in range(n):
        dma(i).wait()
        if i + 2 < n:
            dma(i + 2).start()


@functools.partial(jax.jit, static_argnames=("size", "interpret"))
def copy_rows(src, start, *, size: int, interpret=None):
    """Rows ``[start, start+size)`` of a stacked ``(N, W)`` buffer, moved
    by the double-buffered DMA pipeline.  ``start`` may be traced (it is
    the relay scan's stop index); ``size`` is static.  Bit-exact to
    ``jax.lax.dynamic_slice_in_dim(src, start, size)``."""
    interpret = _interpret_default() if interpret is None else interpret
    n, w = src.shape
    chunks = _chunk_plan(size, w)
    start = jnp.asarray(start, jnp.int32).reshape((1,))
    return pl.pallas_call(
        functools.partial(_copy_kernel, chunks=chunks),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((size, w), src.dtype),
        scratch_shapes=[pltpu.SemaphoreType.DMA((2,))],
        interpret=interpret,
    )(start, src)


def _flat_width(shape) -> int:
    w = 1
    for d in shape[1:]:
        w *= d
    return w


def fetch_slot(stacked, start, size: int, *, squeeze: bool = False,
               interpret=None):
    """Stream-in of one relay stop: ``size`` stacked rows of every leaf of
    a ``(N, ...)`` tree (plain pytree or ``packing.Packed`` — both are
    tree_mapped uniformly), each moved through ``copy_rows``.

    ``squeeze`` drops the leading axis for the G=1 single-layer slot
    (matching ``relay.layer_slice``'s keepdims=False).  Degenerate leaves
    (empty rows) fall back to a plain dynamic slice — there is nothing
    for a DMA pipeline to overlap.
    """
    def one(a):
        w = _flat_width(a.shape)
        if a.shape[0] == 0 or w == 0:
            out = jax.lax.dynamic_slice_in_dim(a, start, size, axis=0)
        else:
            out = copy_rows(a.reshape((a.shape[0], w)), start,
                            size=size, interpret=interpret)
            out = out.reshape((size,) + a.shape[1:])
        return out[0] if squeeze else out
    return jax.tree.map(one, stacked)


def writeback_slot(tree, *, interpret=None):
    """Write-back of one relay stop's products (updated weights/opt
    slots, shipped grads, boundary stash): the same DMA pipeline run in
    the device->EPS direction — an identity copy over the produced
    buffer, chunked and semaphore-paced, issued BEFORE the host
    placement so the outbound transfer is pipelined like the inbound
    one.  The whole leaf moves as ONE flat row split into two half-row
    chunks — a per-row plan over an arbitrary product leaf could unroll
    thousands of DMA starts.  Leaves too small to chunk pass through
    untouched."""
    def one(a):
        if a.ndim == 0 or a.size < 2:
            return a
        out = copy_rows(a.reshape((1, a.size)), 0, size=1,
                        interpret=interpret)
        return out.reshape(a.shape)
    return jax.tree.map(one, tree)
