"""Pallas fused RMSNorm (forward).

Every L2L layer boundary runs a norm on the streamed activations; fusing
the mean-square reduction with the scale keeps it one HBM round trip.
Rows are tiled in VMEM blocks of (block_rows, d); the feature dim stays
whole (d <= a few K for all assigned archs, well within VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm_2d(x, scale, *, eps=1e-6, block_rows=256, interpret=True):
    """x: (R, d), scale: (d,) -> (R, d)."""
    R, d = x.shape
    block_rows = min(block_rows, R)
    assert R % block_rows == 0
    kern = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kern,
        grid=(R // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), x.dtype),
        interpret=interpret,
    )(x, scale)
