"""Jit'd public wrappers around the Pallas kernels.

On this container (CPU backend) the kernels execute in interpret mode —
the TPU lowering is the target, interpret is the validation harness.
``interpret`` defaults to True unless a TPU backend is present.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import functools

from repro.kernels.flash_attention import (flash_attention_bwd_bhsd,
                                           flash_attention_fwd_bhsd)
from repro.kernels.fused_adam import fused_adam_flat
from repro.kernels.rmsnorm import rmsnorm_2d


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Differentiable flash attention (custom VJP: FA-2 recompute backward).
# The L2L engine's per-layer vjp recompute hits this twice per layer per
# microbatch; the recompute backward keeps zero probability blocks in HBM.
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _fa(q, k, v, causal, window, soft_cap, block_q, block_k, interpret):
    o, _ = flash_attention_fwd_bhsd(
        q, k, v, causal=causal, window=window, soft_cap=soft_cap,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return o


def _fa_fwd(q, k, v, causal, window, soft_cap, block_q, block_k, interpret):
    o, lse = flash_attention_fwd_bhsd(
        q, k, v, causal=causal, window=window, soft_cap=soft_cap,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return o, (q, k, v, o, lse)


def _fa_bwd(causal, window, soft_cap, block_q, block_k, interpret, res, do):
    assert soft_cap == 0.0, "soft-capped attention bwd not supported"
    q, k, v, o, lse = res
    dq, dk, dv = flash_attention_bwd_bhsd(
        q, k, v, o, lse, do, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return dq, dk, dv


_fa.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(q, k, v, *, causal=True, window=0, soft_cap=0.0,
                    block_q=128, block_k=128, interpret=None):
    """q,k,v: (B,S,H,D) (model layout) -> (B,S,H,D).  Differentiable
    (custom VJP with recompute backward)."""
    interpret = _interpret_default() if interpret is None else interpret
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = _fa(qt, kt, vt, causal, window, soft_cap, block_q, block_k,
            interpret)
    return o.transpose(0, 2, 1, 3)


def fused_adam(p, g, m, v, a, clip_scale, *, b1=0.9, b2=0.999, eps=1e-8,
               wd=0.0, wd_form=None, interpret=None):
    """Arbitrary-shaped params: flattens, pads to the block size, runs the
    fused kernel, restores shape.  Returns (p', m', v')."""
    interpret = _interpret_default() if interpret is None else interpret
    shape = p.shape
    n = p.size
    block = min(16384, max(128, 1 << (n - 1).bit_length()))
    block = min(block, 16384)
    pad = (-n) % block
    def prep(x, dt):
        return jnp.pad(x.reshape(-1).astype(dt), (0, pad))
    p2, m2, v2 = fused_adam_flat(
        prep(p, p.dtype), prep(g, jnp.float32), prep(m, jnp.float32),
        prep(v, jnp.float32), jnp.asarray(a, jnp.float32),
        jnp.asarray(clip_scale, jnp.float32),
        b1=b1, b2=b2, eps=eps, wd=wd, wd_form=wd_form, block=block,
        interpret=interpret)
    unpad = lambda x: x[:n].reshape(shape)
    return unpad(p2), unpad(m2), unpad(v2)


def rmsnorm(x, scale, *, eps=1e-6, interpret=None):
    """x: (..., d) -> same shape.  Forward only — see ``rmsnorm_diff``."""
    interpret = _interpret_default() if interpret is None else interpret
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    r = x2.shape[0]
    # pad rows to a friendly block
    block = min(256, r)
    pad = (-r) % block
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    o = rmsnorm_2d(x2, scale, eps=eps, block_rows=block, interpret=interpret)
    return o[:r].reshape(shape)


# ---------------------------------------------------------------------------
# Differentiable fused RMSNorm: Pallas forward, reference-recompute backward
# (pallas_call has no transpose rule; the bwd re-derives from (x, scale) —
# the same recompute discipline the flash-attention VJP above uses).  This
# is what models/common.apply_norm dispatches to when the fused path is
# enabled (REPRO_PALLAS_RMSNORM / use_pallas_rmsnorm).
# ---------------------------------------------------------------------------
def _rmsnorm_reference(x, scale, eps):
    # must mirror models.common.apply_norm's rmsnorm branch exactly — the
    # backward below differentiates THIS
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    out = xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rmsnorm_ad(x, scale, eps, interpret):
    return rmsnorm(x, scale, eps=eps, interpret=interpret)


def _rmsnorm_ad_fwd(x, scale, eps, interpret):
    return rmsnorm(x, scale, eps=eps, interpret=interpret), (x, scale)


def _rmsnorm_ad_bwd(eps, interpret, res, g):
    x, scale = res
    _, vjp = jax.vjp(lambda xx, ss: _rmsnorm_reference(xx, ss, eps),
                     x, scale)
    return vjp(g)


_rmsnorm_ad.defvjp(_rmsnorm_ad_fwd, _rmsnorm_ad_bwd)


def rmsnorm_diff(x, scale, *, eps=1e-6, interpret=None):
    """Differentiable fused RMSNorm: x (..., d), scale (d,) -> (..., d)."""
    interpret = _interpret_default() if interpret is None else interpret
    return _rmsnorm_ad(x, scale, eps, interpret)
