"""Pallas fused Adam (+ clip scale) update.

Direct answer to the paper's Fig. 6: "Optimizer (gradient clipping and
update)" is 25% of L2L step time because the reference EPS runs an unfused
optimizer.  One fused elementwise kernel reads (p, g, m, v) once, applies
the clip scale, both moment updates and the parameter delta, and writes
(p', m', v') once — 7 HBM streams instead of the ~17 of an unfused chain,
and zero temp traffic.

Scalars (effective step size ``a`` with bias correction baked in, clip
scale) arrive via SMEM so one compiled kernel serves every step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _adam_kernel(scal_ref, p_ref, g_ref, m_ref, v_ref,
                 po_ref, mo_ref, vo_ref, *, b1, b2, eps, wd, wd_form):
    a = scal_ref[0]          # lr * sqrt(1-b2^t)/(1-b1^t)
    clip = scal_ref[1]       # gradient scale from clipping
    g = g_ref[...].astype(jnp.float32) * clip
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * g * g
    p = p_ref[...].astype(jnp.float32)
    # wd_form is static and keyed on the optimizer FAMILY (not wd's
    # value): each branch reproduces the per-leaf optimizer's exact
    # association — adamw: p - a*(m/(sqrt v+eps) + wd*p), even at wd=0;
    # adam: p - (a*m)/(sqrt v+eps) — so packed updates are bit-identical
    # to optim.adam / optim.adamw.
    if wd_form:
        upd = m / (jnp.sqrt(v) + eps) + wd * p
        po_ref[...] = (p - a * upd).astype(po_ref.dtype)
    else:
        po_ref[...] = (p - a * m / (jnp.sqrt(v) + eps)).astype(po_ref.dtype)
    mo_ref[...] = m
    vo_ref[...] = v


@functools.partial(jax.jit, static_argnames=(
    "b1", "b2", "eps", "wd", "wd_form", "block", "interpret"))
def fused_adam_flat(p, g, m, v, a, clip_scale, *, b1=0.9, b2=0.999,
                    eps=1e-8, wd=0.0, wd_form=None, block=16384,
                    interpret=True):
    """All arrays 1-D of equal length (pad to block multiple).  ``a`` and
    ``clip_scale`` are f32 scalars (traced).  ``wd_form`` forces the
    adamw update association even when wd == 0 (None = infer from wd)."""
    n = p.shape[0]
    block = min(block, n)
    assert n % block == 0, f"{n} % {block}"
    scal = jnp.stack([a.astype(jnp.float32),
                      clip_scale.astype(jnp.float32)])
    kern = functools.partial(_adam_kernel, b1=b1, b2=b2, eps=eps, wd=wd,
                             wd_form=bool(wd) if wd_form is None
                             else wd_form)
    grid = (n // block,)
    bspec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  bspec, bspec, bspec, bspec],
        out_specs=(bspec, bspec, bspec),
        out_shape=(jax.ShapeDtypeStruct((n,), p.dtype),
                   jax.ShapeDtypeStruct((n,), jnp.float32),
                   jax.ShapeDtypeStruct((n,), jnp.float32)),
        interpret=interpret,
    )(scal, p, g, m, v)
