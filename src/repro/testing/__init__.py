"""Deterministic fault injectors for the chaos suite
(tests/test_faults.py) — see ``repro.testing.faults``."""
