"""Deterministic fault injection — the chaos side of the resilience story.

Every injector here is SEEDED and side-effect-explicit, so the chaos
suite (tests/test_faults.py) can reproduce a failure byte-for-byte:

* ``corrupt_file`` / ``corrupt_snapshot`` — truncate or bit-flip a
  checkpoint file at a seeded offset (simulating a half-written snapshot
  on a filesystem without atomic rename, or disk rot in place).
* ``launch_train`` / ``kill_at_step`` — run the real training driver as
  a subprocess and deliver SIGTERM/SIGKILL when a given step's log line
  appears (preemption mid-run, hard crash mid-run).
* ``poison_batch`` — place a NaN into a batch so every gradient of that
  step is non-finite (what a corrupt data shard or an overflow does),
  exercising ``ExecutionConfig.skip_nonfinite``.
* ``steal_pages`` / ``restore_pages`` — starve the serve page pool so
  admission blocks and pending deadlines fire.
* ``snapshot_checksums`` — a snapshot's per-array crc32 list; two
  training runs whose final snapshots share it are bit-identical.
* ``inject_io_error`` / ``inject_io_latency`` / ``corrupt_segment`` —
  the storage-tier chaos: seeded EIO/latency injectors installed into a
  ``SegmentStore``'s read-path ``fault_hook`` (transient-retry and
  retry-budget-exhaustion paths) and in-place segment bit rot (the
  quarantine-and-rebuild path).
"""
from __future__ import annotations

import errno
import os
import re
import signal
import subprocess
import sys
import threading
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.checkpoint import io as ckpt_io

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
_STEP_RE = re.compile(r"^step\s+(\d+)")


# ===========================================================================
# Checkpoint corruption
# ===========================================================================
def corrupt_file(path: str, mode: str = "bitflip", seed: int = 0) -> None:
    """Corrupt one file in place.  ``bitflip`` flips a single bit at a
    seeded offset; ``truncate`` cuts the file to a seeded fraction of
    its length (a partial write)."""
    size = os.path.getsize(path)
    assert size > 0, f"cannot corrupt empty file {path}"
    rng = np.random.default_rng(seed)
    if mode == "bitflip":
        off = int(rng.integers(0, size))
        bit = int(rng.integers(0, 8))
        with open(path, "r+b") as f:
            f.seek(off)
            byte = f.read(1)[0]
            f.seek(off)
            f.write(bytes([byte ^ (1 << bit)]))
    elif mode == "truncate":
        keep = int(size * float(rng.uniform(0.2, 0.8)))
        with open(path, "r+b") as f:
            f.truncate(keep)
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")


def corrupt_snapshot(snapshot_dir: str, mode: str = "bitflip",
                     target: str = "arrays", seed: int = 0) -> str:
    """Corrupt a snapshot directory's ``arrays.npz`` (or its manifest);
    returns the path of the file that was damaged."""
    name = ckpt_io.ARRAYS if target == "arrays" else ckpt_io.MANIFEST
    path = os.path.join(snapshot_dir, name)
    corrupt_file(path, mode=mode, seed=seed)
    return path


def snapshot_checksums(directory: str, step: Optional[int] = None,
                       prefix: str = "ckpt") -> List[int]:
    """The per-array crc32 list of a snapshot (newest good one when
    ``step`` is None) — equality means bit-identical state on disk."""
    if step is None:
        step = ckpt_io.latest_good(directory, prefix)
        assert step is not None, f"no good snapshot in {directory}"
    manifest = ckpt_io.read_manifest(
        ckpt_io.snapshot_path(directory, step, prefix))
    assert manifest is not None
    return list(manifest["crc32"])


# ===========================================================================
# Storage-tier (SegmentStore) fault injection
# ===========================================================================
class _IOFault:
    """Install-state of one read-path injector (thread-safe: the store's
    prefetch ring issues reads from a pool).  ``raised``/``delayed``
    count the reads the injector actually touched."""

    def __init__(self):
        self.lock = threading.Lock()
        self.raised = 0
        self.delayed = 0
        self.seen = 0


def inject_io_error(store, *, fail_reads: int = 1,
                    err: int = errno.EIO, match: str = "",
                    persistent: bool = False) -> _IOFault:
    """Make the store's next ``fail_reads`` physical segment reads (those
    whose path contains ``match``) raise ``OSError(err)``.  EIO is in the
    store's transient set, so ``fail_reads <= retries`` exercises the
    backoff-retry-recover path and ``persistent=True`` (every matching
    read fails forever) the budget-exhausted hard ``TierReadError``.
    Chains with any previously installed hook; returns the counter."""
    fault = _IOFault()
    prev = store.fault_hook

    def hook(path: str, offset: int, length: int) -> None:
        if prev is not None:
            prev(path, offset, length)
        with fault.lock:
            if match not in path:
                return
            fault.seen += 1
            if persistent or fault.raised < fail_reads:
                fault.raised += 1
                raise OSError(err, f"injected {errno.errorcode.get(err)}")

    store.fault_hook = hook
    return fault


def inject_io_latency(store, *, delay_s: float, jitter_s: float = 0.0,
                      seed: int = 0, match: str = "") -> _IOFault:
    """Add ``delay_s`` (+ seeded uniform jitter up to ``jitter_s``) of
    sleep before every matching physical segment read — a congested or
    throttled NVMe.  Reads still succeed; this widens the window in
    which the prefetch ring, watchdog and retry paths interleave."""
    fault = _IOFault()
    rng = np.random.default_rng(seed)
    prev = store.fault_hook

    def hook(path: str, offset: int, length: int) -> None:
        if prev is not None:
            prev(path, offset, length)
        if match not in path:
            return
        with fault.lock:
            fault.delayed += 1
            extra = float(rng.uniform(0.0, jitter_s)) if jitter_s else 0.0
        time.sleep(delay_s + extra)

    store.fault_hook = hook
    return fault


def corrupt_segment(store, key: str, seg: Optional[str] = None,
                    seed: int = 0) -> str:
    """Bit-flip one seeded byte of a stored segment file IN PLACE (disk
    rot under the store's nose: the manifest stays intact, so the rot is
    only observable through crc verification — at open by a fresh store,
    or at the read that returns the rotten row).  ``seg`` defaults to
    the first segment name in the key's manifest; returns the damaged
    path."""
    manifest = store._read_manifest(key)
    assert manifest is not None, f"no manifest for segment key {key!r}"
    if seg is None:
        seg = sorted(manifest["segs"])[0]
    path = store.seg_path(key, seg)
    corrupt_file(path, mode="bitflip", seed=seed)
    return path


# ===========================================================================
# Training-subprocess preemption / crash
# ===========================================================================
def launch_train(argv: List[str]) -> subprocess.Popen:
    """Start ``repro.launch.train`` with the given CLI args as a real
    subprocess (line-buffered stdout so the kill trigger sees step lines
    as they happen)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train", *argv],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True, bufsize=1, env=env)


def kill_at_step(proc: subprocess.Popen, step: int,
                 sig: int = signal.SIGTERM,
                 timeout: float = 300.0) -> Tuple[int, str]:
    """Watch the subprocess's step log and deliver ``sig`` as soon as a
    ``step <n>`` line with n >= step appears; returns (returncode,
    full output).  SIGTERM exercises the graceful finish-save-exit
    path; SIGKILL a hard crash (the run must then resume from its last
    periodic snapshot)."""
    lines = []
    sent = False
    assert proc.stdout is not None
    for line in proc.stdout:
        lines.append(line)
        m = _STEP_RE.match(line)
        if not sent and m and int(m.group(1)) >= step:
            proc.send_signal(sig)
            sent = True
            if sig == signal.SIGKILL:
                break
    proc.stdout.close()
    rc = proc.wait(timeout=timeout)
    return rc, "".join(lines)


def run_train(argv: List[str], timeout: float = 600.0) -> str:
    """Run the training driver to completion; returns its output
    (raises on nonzero exit)."""
    proc = launch_train(argv)
    assert proc.stdout is not None
    out = proc.stdout.read()
    proc.stdout.close()
    rc = proc.wait(timeout=timeout)
    assert rc == 0, f"train exited {rc}:\n{out}"
    return out


# ===========================================================================
# NaN injection (bad data shard / numeric overflow)
# ===========================================================================
def poison_batch(batch: dict, key: str = "mask", seed: int = 0) -> dict:
    """A copy of ``batch`` with one NaN planted in a float field (the
    loss weight mask by default): the step's loss — and therefore every
    gradient the backward relay produces, whatever the (G, prefetch,
    pack, K) point — becomes non-finite, the exact signature of a
    corrupt data shard or activation overflow."""
    rng = np.random.default_rng(seed)
    out = dict(batch)
    arr = np.array(batch[key], copy=True)
    assert arr.dtype.kind == "f", f"{key} is not a float field"
    idx = tuple(int(rng.integers(0, s)) for s in arr.shape)
    arr[idx] = np.nan
    out[key] = arr
    return out


# ===========================================================================
# Serve page-pool starvation
# ===========================================================================
def steal_pages(scheduler, k: int) -> List[int]:
    """Remove ``k`` physical pages from the scheduler's free pool
    (simulating exhaustion/leak): admission of any request whose
    reservation no longer fits blocks until pages return — or until its
    deadline evicts it.  Returns the stolen page ids for
    ``restore_pages``."""
    assert k <= len(scheduler.free_pages), "cannot steal claimed pages"
    stolen = [scheduler.free_pages.pop() for _ in range(k)]
    return stolen


def restore_pages(scheduler, stolen: List[int]) -> None:
    """Hand stolen pages back (the leak healed)."""
    scheduler.free_pages.extend(stolen)
