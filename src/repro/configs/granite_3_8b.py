"""Granite-3 8B [hf:ibm-granite/granite-3.0-2b-base family].

Dense decoder, 40L, d_model=4096, 32 heads (GQA kv=8), d_ff=12800,
vocab=49155.  RMSNorm, SwiGLU, tied embeddings.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b", family="dense",
        source="hf:ibm-granite/granite-3.0-2b-base",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
        d_ff=12800, vocab_size=49155,
        norm_type="rmsnorm", gated_mlp=True, act="silu",
        tie_embeddings=True, rope_theta=10_000_000.0, max_seq_len=8192,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="granite-3-8b-smoke", n_layers=2, d_model=256, n_heads=8,
        n_kv_heads=2, d_head=32, d_ff=512, vocab_size=512, max_seq_len=256,
        attn_chunk=0)


register("granite-3-8b", full, smoke)
