"""BERT-Large — the paper's own model (Table 1: 24L, hidden 1024,
intermediate 4096, max seq 512, ADAM).

The paper fine-tunes sequence classification; our framework exercises the
same backbone as a layered LM stack (the L2L schedule is agnostic to the
head).  Depth variants (12/24/48/96 layers, Table 2) are produced with
``.replace(n_layers=...)`` by the memory benchmark.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="bert-large", family="dense", source="arXiv:1810.04805 / paper",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
        d_ff=4096, vocab_size=30522,
        norm_type="layernorm", gated_mlp=False, act="gelu",
        qkv_bias=True, o_bias=True, max_seq_len=512,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="bert-large-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_head=32, d_ff=256, vocab_size=512, max_seq_len=128,
        attn_chunk=0)


register("bert-large", full, smoke)
