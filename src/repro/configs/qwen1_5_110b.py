"""Qwen1.5-110B [hf:Qwen/Qwen1.5-0.5B family, scaled card].

Dense decoder, 80L, d_model=8192, 64 heads (GQA kv=8), d_ff=49152,
vocab=152064.  QKV projection biases (the Qwen1.5 signature), RMSNorm,
SwiGLU, untied embeddings.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b", family="dense", source="hf:Qwen/Qwen1.5-0.5B",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=49152, vocab_size=152064,
        qkv_bias=True, norm_type="rmsnorm", gated_mlp=True, act="silu",
        rope_theta=1_000_000.0, max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="qwen1.5-110b-smoke", n_layers=2, d_model=256, n_heads=8,
        n_kv_heads=2, d_head=32, d_ff=512, vocab_size=512, max_seq_len=256,
        attn_chunk=0)


register("qwen1.5-110b", full, smoke)
