"""ChatGLM3-6B [arXiv:2406.12793].

Dense decoder, 28L, d_model=4096, 32 heads (GQA kv=2), d_ff=13696,
vocab=65024.  2D RoPE — rotary applied to half of each head dim
(rope_fraction=0.5) — and QKV biases.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b", family="dense", source="arXiv:2406.12793",
        n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_head=128,
        d_ff=13696, vocab_size=65024,
        qkv_bias=True, rope_fraction=0.5, norm_type="rmsnorm",
        gated_mlp=True, act="silu", max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="chatglm3-6b-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_head=32, d_ff=256, vocab_size=512, max_seq_len=128,
        attn_chunk=0)


register("chatglm3-6b", full, smoke)
