"""DeepSeek-V2-Lite (16B total / 2.4B active) [arXiv:2405.04434].

27L, d_model=2048, 16 heads, MLA attention with kv_lora_rank=512
(qk_nope=128, qk_rope=64, v=128), vocab=102400.  MoE FFN: 64 routed experts
top-6 + 2 shared experts, per-expert d_ff=1408; layer 0 uses a dense FFN
(d_ff=10944).

Note: the assignment bracket mentions "160 routed" which is full DeepSeek-V2;
the primary spec line says 64e top-6 (= the Lite model card) — we follow the
primary spec.  See DESIGN.md §5.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe", source="arXiv:2405.04434",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
        d_ff=1408, vocab_size=102400,
        use_mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        v_head_dim=128,
        n_experts=64, n_shared_experts=2, experts_per_token=6,
        d_ff_expert=1408, d_ff_dense=10944, first_dense_layers=1,
        norm_type="rmsnorm", gated_mlp=True, act="silu", max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="deepseek-v2-lite-16b-smoke", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_head=32, d_ff=128, vocab_size=512,
        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        n_experts=4, n_shared_experts=1, experts_per_token=2,
        d_ff_expert=64, d_ff_dense=128, first_dense_layers=1,
        max_seq_len=128, attn_chunk=0)


register("deepseek-v2-lite-16b", full, smoke)
