"""InternVL2-1B [arXiv:2404.16821].

VLM: InternViT-300M vision encoder (STUB per assignment — ``input_specs``
provides precomputed patch embeddings) + Qwen2-0.5B language backbone:
24L, d_model=896, 14 heads (GQA kv=2), d_ff=4864, vocab=151655, QKV bias,
tied embeddings.  An MLP projector maps ViT features (1024) to d_model.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b", family="vlm", source="arXiv:2404.16821",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_head=64,
        d_ff=4864, vocab_size=151655,
        qkv_bias=True, norm_type="rmsnorm", gated_mlp=True, act="silu",
        tie_embeddings=True, rope_theta=1_000_000.0,
        is_vlm=True, n_patches=256, vit_dim=1024, max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="internvl2-1b-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_head=32, d_ff=256, vocab_size=512,
        n_patches=4, vit_dim=48, max_seq_len=128, attn_chunk=0)


register("internvl2-1b", full, smoke)
