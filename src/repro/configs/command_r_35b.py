"""Command R 35B [hf:CohereForAI/c4ai-command-r-v01].

Dense decoder, 40L, d_model=8192, 64 heads (GQA kv=8), d_ff=22528,
vocab=256000.  Cohere-style parallel residual block (attention and FFN both
read one pre-norm), no projection biases, tied embeddings, large rope theta.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", family="dense",
        source="hf:CohereForAI/c4ai-command-r-v01",
        n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=22528, vocab_size=256000,
        parallel_block=True, norm_type="layernorm", gated_mlp=True,
        act="silu", tie_embeddings=True, rope_theta=8_000_000.0,
        max_seq_len=131072,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="command-r-35b-smoke", n_layers=2, d_model=256, n_heads=8,
        n_kv_heads=2, d_head=32, d_ff=512, vocab_size=512, max_seq_len=256,
        attn_chunk=0)


register("command-r-35b", full, smoke)
