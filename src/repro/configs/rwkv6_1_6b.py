"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892].

Attention-free RNN: 24L, d_model=2048, d_ff=7168, vocab=65536; head size 64
(32 wkv heads), data-dependent decay via DDLerp low-rank modulation.
Decode state is O(1) in context — long_500k is native.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm", source="arXiv:2404.05892",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=7168, vocab_size=65536,
        rwkv_head_dim=64, rwkv_lora=64,
        norm_type="layernorm", max_seq_len=1_000_000,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="rwkv6-1.6b-smoke", n_layers=2, d_model=128, d_ff=256,
        vocab_size=512, rwkv_head_dim=32, rwkv_lora=16, n_heads=4,
        n_kv_heads=4, max_seq_len=128)


register("rwkv6-1.6b", full, smoke)
