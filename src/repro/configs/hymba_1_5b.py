"""Hymba-1.5B [arXiv:2411.13676].

Hybrid-head architecture: every layer runs attention heads and Mamba (SSM)
heads **in parallel** on the same input, fused by learned per-channel scales
and a mean.  32L, d_model=1600, 25 heads (GQA kv=5), d_ff=5504, vocab=32001,
ssm_state=16.  Attention heads use a sliding window (as in the paper's
efficient configuration), which also makes long_500k decode native.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid", source="arXiv:2411.13676",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_head=64,
        d_ff=5504, vocab_size=32001,
        ssm_state=16, ssm_conv=4, sliding_window=2048,
        norm_type="rmsnorm", gated_mlp=True, act="silu",
        tie_embeddings=True, max_seq_len=8192,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="hymba-1.5b-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_head=32, d_ff=256, vocab_size=512, ssm_state=4,
        sliding_window=32, max_seq_len=128, attn_chunk=0)


register("hymba-1.5b", full, smoke)
