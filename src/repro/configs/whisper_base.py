"""Whisper-base [arXiv:2212.04356].

Encoder-decoder speech model.  The mel-spectrogram + conv frontend is a STUB
per the assignment: ``input_specs`` provides precomputed frame embeddings
(B, 1500, d_model).  Backbone: 6 encoder + 6 decoder layers, d_model=512,
8 heads (MHA — "GQA kv=8" with 8 heads), d_ff=2048, vocab=51865.
LayerNorm, GELU (non-gated), projection biases, tied decoder embeddings.

Skips: ``long_500k`` (see DESIGN.md §5 — bounded source/target lengths make
a 524k-token decode meaningless for the family).
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio", source="arXiv:2212.04356",
        n_layers=6, n_encoder_layers=6, is_encoder_decoder=True,
        d_model=512, n_heads=8, n_kv_heads=8, d_head=64,
        d_ff=2048, vocab_size=51865,
        norm_type="layernorm", gated_mlp=False, act="gelu",
        qkv_bias=True, o_bias=True, tie_embeddings=True,
        n_frames=1500, frontend_dim=512, max_target_positions=448,
        max_seq_len=4096,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="whisper-base-smoke", n_layers=2, n_encoder_layers=2,
        d_model=128, n_heads=4, n_kv_heads=4, d_head=32, d_ff=256,
        vocab_size=512, n_frames=16, frontend_dim=128, max_seq_len=128,
        attn_chunk=0)


register("whisper-base", full, smoke)
