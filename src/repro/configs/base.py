"""Model configuration system.

One ``ModelConfig`` describes an architecture from the assigned pool.  The
config is a frozen dataclass so it can be hashed into jit static args.  Every
assigned architecture gets one module in this package that builds its exact
config (``full()``) plus a reduced smoke-test variant (``smoke()``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

# ---------------------------------------------------------------------------
# Architecture families
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
VLM = "vlm"
AUDIO = "audio"


@dataclass(frozen=True)
class ModelConfig:
    # -- identity ----------------------------------------------------------
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""                  # citation from the assignment table

    # -- core dims ---------------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 64
    d_ff: int = 1024
    vocab_size: int = 32000

    # -- attention flavour ---------------------------------------------------
    qkv_bias: bool = False            # qwen1.5 style
    o_bias: bool = False
    parallel_block: bool = False      # command-r: attn and FFN in parallel
    rope_fraction: float = 1.0        # chatglm3: rope on half the head dims
    rope_theta: float = 10000.0
    sliding_window: int = 0           # 0 = full attention
    # long-context serving variant: ring-buffer window used ONLY for the
    # long_500k shape on otherwise-full-attention archs (see DESIGN.md §5)
    long_context_window: int = 4096

    # -- MLA (deepseek-v2) ---------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # -- MoE -----------------------------------------------------------------
    n_experts: int = 0                # routed experts
    n_shared_experts: int = 0
    experts_per_token: int = 0
    d_ff_expert: int = 0              # per-expert intermediate
    d_ff_dense: int = 0               # intermediate of dense layers in a MoE stack
    first_dense_layers: int = 0       # deepseek-v2: layer 0 is dense
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # -- SSM / RWKV ----------------------------------------------------------
    ssm_state: int = 0                # mamba state size (hymba)
    ssm_conv: int = 4                 # depthwise conv width in the SSM branch
    rwkv_head_dim: int = 64           # rwkv6 "Finch"
    rwkv_lora: int = 64               # rank of the data-dependent-decay LoRA
    rwkv_chunk: int = 0               # chunked-parallel wkv (0 = step scan)

    # -- encoder/decoder (whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    n_frames: int = 1500              # stubbed audio frontend output length
    frontend_dim: int = 0             # stub embedding dim (== d_model for audio)
    max_target_positions: int = 448

    # -- VLM (internvl) --------------------------------------------------------
    is_vlm: bool = False
    n_patches: int = 256              # stubbed ViT frontend output length
    vit_dim: int = 1024               # InternViT-300M hidden (stub input dim)

    # -- norms / act / misc ----------------------------------------------------
    norm_type: str = "rmsnorm"        # rmsnorm | layernorm
    norm_eps: float = 1e-6
    act: str = "silu"                 # silu (gated) | gelu (plain mlp)
    gated_mlp: bool = True
    tie_embeddings: bool = False
    logit_soft_cap: float = 0.0       # grok uses 30.0
    max_seq_len: int = 8192

    # -- compute -----------------------------------------------------------
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"      # master params (EPS-resident)
    use_pallas: bool = False          # use Pallas flash-attention kernel
    attn_chunk: int = 512             # KV chunk for memory-efficient attention
    # -- beyond-paper perf knobs (see EXPERIMENTS.md §Perf) ------------------
    grouped_decode_attn: bool = False  # GQA decode w/o kv-head expansion
    moe_ep_constraint: bool = False    # sharding constraints on MoE dispatch

    # ------------------------------------------------------------------
    @property
    def n_q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == SSM

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (for roofline MODEL_FLOPS) -----------------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count.  ``active_only`` counts only the
        per-token-active expert params for MoE (top-k + shared)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == SSM:  # rwkv6
            # time-mix: r,k,v,g,w projections + out  (~6 d^2) + channel mix
            per_layer = 6 * d * d + d * self.d_ff + self.d_ff * d + d * d
        else:
            if self.use_mla:
                r = self.kv_lora_rank
                qd = self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                per_layer += d * qd                      # q proj
                per_layer += d * (r + self.qk_rope_dim)  # kv down + k_rope
                per_layer += r * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                per_layer += self.n_heads * self.v_head_dim * d
            else:
                per_layer += d * self.n_heads * self.d_head        # q
                per_layer += 2 * d * self.n_kv_heads * self.d_head  # k,v
                per_layer += self.n_heads * self.d_head * d         # o
            if self.family == HYBRID:
                dI = self.d_model
                per_layer += 2 * d * dI + dI * self.ssm_state * 2 + dI * d
            # mlp / moe
            if self.n_experts:
                fe = self.d_ff_expert
                n_mats = 3 if self.gated_mlp else 2
                routed = self.n_experts * n_mats * d * fe
                shared = self.n_shared_experts * n_mats * d * fe
                if active_only:
                    routed = self.experts_per_token * n_mats * d * fe
                per_layer += routed + shared + d * self.n_experts
            else:
                n_mats = 3 if self.gated_mlp else 2
                per_layer += n_mats * d * ff
        total = emb + self.n_layers * per_layer
        if self.is_encoder_decoder:
            # encoder layers: self-attn + mlp; decoder already counted has
            # an extra cross-attn block
            enc = self.n_encoder_layers * (4 * d * d + 2 * d * ff)
            xattn = self.n_layers * 4 * d * d
            total += enc + xattn
        if self.first_dense_layers and self.n_experts:
            # first layer(s) use the dense FFN width instead of MoE
            n_mats = 3 if self.gated_mlp else 2
            fe = self.d_ff_expert
            moe_per = (self.n_experts if not active_only else
                       self.experts_per_token) * n_mats * d * fe \
                + self.n_shared_experts * n_mats * d * fe + d * self.n_experts
            dense_per = n_mats * d * (self.d_ff_dense or ff)
            total += self.first_dense_layers * (dense_per - moe_per)
        return int(total)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(arch_id: str, full_fn, smoke_fn):
    _REGISTRY[arch_id] = (full_fn, smoke_fn)


def get_config(arch_id: str, variant: str = "full") -> ModelConfig:
    if arch_id not in _REGISTRY:
        _load_all()
    full_fn, smoke_fn = _REGISTRY[arch_id]
    return full_fn() if variant == "full" else smoke_fn()


def list_archs():
    _load_all()
    return sorted(_REGISTRY.keys())


def _load_all():
    # import registers
    from repro.configs import (  # noqa: F401
        command_r_35b, internvl2_1b, qwen1_5_110b, hymba_1_5b, whisper_base,
        chatglm3_6b, deepseek_v2_lite_16b, granite_3_8b, grok_1_314b,
        rwkv6_1_6b, bert_large)


# ---------------------------------------------------------------------------
# Input shapes from the assignment
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
