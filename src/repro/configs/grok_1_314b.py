"""Grok-1 314B [hf:xai-org/grok-1].

MoE decoder, 64L, d_model=6144, 48 heads (GQA kv=8), vocab=131072.
8 routed experts top-2, per-expert d_ff=32768, gated GELU, logit
soft-capping at 30 (grok signature), RMSNorm.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family="moe", source="hf:xai-org/grok-1",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
        d_ff=32768, vocab_size=131072,
        n_experts=8, n_shared_experts=0, experts_per_token=2,
        d_ff_expert=32768,
        norm_type="rmsnorm", gated_mlp=True, act="gelu",
        logit_soft_cap=30.0, max_seq_len=8192,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="grok-1-314b-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_head=32, d_ff=256, vocab_size=512,
        n_experts=4, experts_per_token=2, d_ff_expert=128,
        max_seq_len=128, attn_chunk=0)


register("grok-1-314b", full, smoke)
