PY ?= python

.PHONY: verify test chaos bench bench-relay bench-pack bench-group \
	bench-stash bench-serve bench-tier bench-transport bench-compile \
	quickstart

# tier-1 verification (quick: slow multi-device subprocess tests deselected)
verify:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow"

# the full suite, slow marks included
test:
	PYTHONPATH=src $(PY) -m pytest -q

# the fault-injection chaos suite, slow kill/resume combos included:
# corrupt snapshots, SIGTERM/SIGKILL mid-run + bit-identical resume,
# NaN poisoning across the knob grid, serve deadline eviction/starvation
chaos:
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_faults.py

# all paper tables/figures (includes the relay-overlap A/B)
bench:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick

# just the relay-overlap A/B; writes BENCH_relay.json at the repo root
bench-relay:
	PYTHONPATH=src $(PY) benchmarks/fig_overlap.py --tiny

# packed-relay A/B (pack x weight_stream x prefetch); writes
# BENCH_pack.json at the repo root and fails on a >10% geometric-mean
# packed-vs-unpacked throughput regression across the combos
bench-pack:
	PYTHONPATH=src $(PY) benchmarks/fig_pack.py --tiny

# layer-group relay sweep (layers_per_relay x prefetch x pack) with the
# analytic G*(1+k) footprint per point; writes BENCH_group.json at the
# repo root — the footprint-vs-throughput curve
bench-group:
	PYTHONPATH=src $(PY) benchmarks/fig_group.py --tiny

# constant-memory stash sweep (stash_every x group x prefetch) pairing
# steps/s with the analytic ceil(N/K) stash footprint + recompute
# counts; writes BENCH_stash.json at the repo root
bench-stash:
	PYTHONPATH=src $(PY) benchmarks/fig_stash.py --tiny

# storage-tier A/B (host-only vs fully-streamed disk tier across
# prefetch depths) + a crc-verified SegmentStore streaming soak; writes
# BENCH_tier.json at the repo root and fails on a >10% geometric-mean
# tier-vs-host-only throughput regression
bench-tier:
	PYTHONPATH=src $(PY) benchmarks/fig_tier.py --tiny

# continuous-batching serve sweep (tok/s + p50/p99 latency vs
# concurrency under Poisson load); writes BENCH_serve.json at the repo
# root and fails when throughput stops scaling with concurrency
bench-serve:
	PYTHONPATH=src $(PY) benchmarks/fig_serve.py --tiny

# relay transport A/B (xla device_put vs pallas double-buffered DMA
# copy kernel, across prefetch depths) with achieved copy/compute
# overlap; writes BENCH_transport.json at the repo root and fails on a
# >10% geometric-mean pallas-vs-xla slowdown
bench-transport:
	PYTHONPATH=src $(PY) benchmarks/fig_transport.py --tiny

# compile-time-vs-depth sweep (segment-scan vs historical unrolled
# driver): trace+lower+compile seconds per depth with the lowered
# while-instance counts; writes BENCH_compile.json at the repo root and
# fails when the segment-scan program's compile time grows with depth
bench-compile:
	PYTHONPATH=src $(PY) benchmarks/fig_compile.py --tiny

quickstart:
	PYTHONPATH=src $(PY) examples/quickstart.py
