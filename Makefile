PY ?= python

# Two failures ship with the seed and are tracked in CHANGES.md/ROADMAP
# (CPU fp noise + MLA decode mismatch); deselect them so `verify` carries
# signal about NEW regressions.  `make test` runs everything, warts and all.
KNOWN_SEED_FAILURES = \
	--deselect tests/test_decode_consistency.py::test_mla_absorbed_decode_matches_naive \
	--deselect tests/test_system.py::test_l2l_and_baseline_learning_curves_match

.PHONY: verify test bench quickstart

# tier-1 verification (quick: slow multi-device subprocess tests deselected)
verify:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow" $(KNOWN_SEED_FAILURES)

# the full suite: slow marks included, known seed failures NOT deselected
test:
	PYTHONPATH=src $(PY) -m pytest -q

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick

quickstart:
	PYTHONPATH=src $(PY) examples/quickstart.py
